//! Dynamic batcher: one thread per dataset route.
//!
//! Compatible requests (same parameterization, solver, schedule, steps,
//! class) are merged into a single integration batch up to `max_batch`
//! rows, or flushed after `max_wait` — the standard latency/throughput
//! dial of serving systems. Padding to the AOT artifact's static batch
//! shapes happens one level down (the PJRT executor); the batcher's job is
//! to fill those shapes as much as possible.

use std::collections::BTreeMap;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::coordinator::hub::EngineHub;
use crate::coordinator::metrics::ServerMetrics;
use crate::coordinator::protocol::{Response, SampleRequest};
use crate::metrics::sample_mean_cov;
use crate::sampler::{run_sampler, RunConfig};
use crate::util::Timer;
use crate::Result;

/// A request waiting in a batch group.
pub struct Pending {
    pub req: SampleRequest,
    pub reply: mpsc::Sender<Response>,
    pub enqueued: Instant,
    pub timer: Timer,
}

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// max rows integrated together (match the largest artifact batch).
    pub max_batch: usize,
    /// flush age for a non-full group.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 256, max_wait: Duration::from_millis(2) }
    }
}

/// Group key: everything that must match for two requests to share one
/// integration batch.
fn group_key(r: &SampleRequest) -> String {
    format!(
        "{}|{}|{}|{}|{:?}",
        r.param.name(),
        r.solver.tag(),
        r.schedule.tag(),
        r.steps,
        r.class
    )
}

/// Run the batcher loop for one dataset until the inbox closes.
pub fn batcher_loop(
    dataset: String,
    hub: Arc<EngineHub>,
    metrics: Arc<ServerMetrics>,
    rx: mpsc::Receiver<Pending>,
    policy: BatchPolicy,
) {
    let mut groups: BTreeMap<String, Vec<Pending>> = BTreeMap::new();
    loop {
        // wait for work, with a timeout so aged groups still flush
        match rx.recv_timeout(policy.max_wait) {
            Ok(p) => {
                groups.entry(group_key(&p.req)).or_default().push(p);
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                // drain and flush everything, then exit
                for (_, g) in std::mem::take(&mut groups) {
                    flush(&dataset, &hub, &metrics, g);
                }
                return;
            }
        }
        // flush full or aged groups
        let now = Instant::now();
        let keys: Vec<String> = groups.keys().cloned().collect();
        for key in keys {
            let rows: usize = groups[&key].iter().map(|p| p.req.n).sum();
            let age = groups[&key]
                .iter()
                .map(|p| now.duration_since(p.enqueued))
                .max()
                .unwrap_or_default();
            if rows >= policy.max_batch || age >= policy.max_wait {
                let g = groups.remove(&key).unwrap();
                flush(&dataset, &hub, &metrics, g);
            }
        }
    }
}

/// Integrate one group and split results back to its requests.
fn flush(dataset: &str, hub: &EngineHub, metrics: &ServerMetrics, group: Vec<Pending>) {
    if group.is_empty() {
        return;
    }
    let batched_with = group.len();
    match run_group(dataset, hub, &group) {
        Ok((samples, nfe, dim)) => {
            let mut offset = 0usize;
            for p in &group {
                let rows = p.req.n;
                let slice = &samples[offset * dim..(offset + rows) * dim];
                offset += rows;
                let stats = sample_mean_cov(slice, dim);
                let resp = Response::SampleOk {
                    n: rows,
                    nfe,
                    mean: stats.mean.clone(),
                    trace_cov: stats.cov.trace(),
                    latency_us: p.timer.elapsed_us(),
                    batched_with,
                    samples: p.req.return_samples.then(|| slice.to_vec()),
                    dim,
                };
                metrics.record_request(dataset, p.timer.elapsed_us(), rows, nfe);
                let _ = p.reply.send(resp);
            }
            metrics.record_batch(dataset, batched_with, offset);
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for p in &group {
                metrics.record_error(dataset);
                let _ = p.reply.send(Response::Err(msg.clone()));
            }
        }
    }
}

/// Integrate the union of a group's rows in one run.
fn run_group(dataset: &str, hub: &EngineHub, group: &[Pending]) -> Result<(Vec<f32>, f64, usize)> {
    let head = &group[0].req;
    let total: usize = group.iter().map(|p| p.req.n).sum();
    let info = hub.info(dataset)?;
    let model = hub.model(dataset)?;
    let grid = hub.schedule(dataset, head.param, &head.schedule, head.steps)?;
    let cfg = RunConfig {
        rows: total,
        seed: head.seed ^ 0x5D3_1E55,
        class: head.class,
        trace: false,
    };
    let out = run_sampler(model.as_ref(), head.param, &grid, &head.solver, info, &cfg)?;
    Ok((out.samples, out.nfe as f64, info.dim))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::Request;
    use crate::model::gmm::testmodel::toy;

    fn mk_request(n: usize, solver: &str) -> SampleRequest {
        let line = format!(
            r#"{{"op":"sample","dataset":"toy","n":{n},"solver":"{solver}","steps":8}}"#
        );
        match Request::parse(&line).unwrap() {
            Request::Sample(s) => s,
            _ => unreachable!(),
        }
    }

    fn spawn_batcher() -> (mpsc::Sender<Pending>, Arc<ServerMetrics>) {
        let hub = Arc::new(EngineHub::from_infos(vec![toy().info]));
        let metrics = Arc::new(ServerMetrics::new());
        let (tx, rx) = mpsc::channel();
        let m2 = metrics.clone();
        std::thread::spawn(move || {
            batcher_loop("toy".into(), hub, m2, rx, BatchPolicy::default())
        });
        (tx, metrics)
    }

    fn submit(tx: &mpsc::Sender<Pending>, req: SampleRequest) -> mpsc::Receiver<Response> {
        let (rtx, rrx) = mpsc::channel();
        tx.send(Pending { req, reply: rtx, enqueued: Instant::now(), timer: Timer::start() })
            .unwrap();
        rrx
    }

    #[test]
    fn compatible_requests_are_batched() {
        let (tx, metrics) = spawn_batcher();
        let rx1 = submit(&tx, mk_request(8, "euler"));
        let rx2 = submit(&tx, mk_request(8, "euler"));
        let r1 = rx1.recv_timeout(Duration::from_secs(10)).unwrap();
        let r2 = rx2.recv_timeout(Duration::from_secs(10)).unwrap();
        for r in [r1, r2] {
            match r {
                Response::SampleOk { n, batched_with, nfe, .. } => {
                    assert_eq!(n, 8);
                    assert_eq!(batched_with, 2);
                    assert_eq!(nfe, 8.0); // euler on 8 steps
                }
                other => panic!("{other:?}"),
            }
        }
        let snap = metrics.snapshot();
        assert!(snap.to_string().contains("toy"));
    }

    #[test]
    fn incompatible_requests_not_merged() {
        let (tx, _m) = spawn_batcher();
        let rx1 = submit(&tx, mk_request(4, "euler"));
        let rx2 = submit(&tx, mk_request(4, "heun"));
        for rx in [rx1, rx2] {
            match rx.recv_timeout(Duration::from_secs(10)).unwrap() {
                Response::SampleOk { batched_with, .. } => assert_eq!(batched_with, 1),
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn every_request_gets_exactly_its_rows_back() {
        let (tx, _m) = spawn_batcher();
        let sizes = [3usize, 17, 5, 1, 9];
        let rxs: Vec<_> = sizes
            .iter()
            .map(|&n| {
                let mut r = mk_request(n, "euler");
                r.return_samples = true;
                submit(&tx, r)
            })
            .collect();
        for (rx, &n) in rxs.iter().zip(&sizes) {
            match rx.recv_timeout(Duration::from_secs(10)).unwrap() {
                Response::SampleOk { samples, dim, .. } => {
                    assert_eq!(samples.unwrap().len(), n * dim);
                }
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn unknown_dataset_in_group_yields_error() {
        let hub = Arc::new(EngineHub::from_infos(vec![toy().info]));
        let metrics = Arc::new(ServerMetrics::new());
        let (tx, rx) = mpsc::channel();
        let m2 = metrics.clone();
        std::thread::spawn(move || {
            batcher_loop("ghost".into(), hub, m2, rx, BatchPolicy::default())
        });
        let mut req = mk_request(2, "euler");
        req.dataset = "ghost".into();
        let (rtx, rrx) = mpsc::channel();
        tx.send(Pending {
            req,
            reply: rtx,
            enqueued: Instant::now(),
            timer: Timer::start(),
        })
        .unwrap();
        match rrx.recv_timeout(Duration::from_secs(10)).unwrap() {
            Response::Err(e) => assert!(e.contains("unknown dataset")),
            other => panic!("{other:?}"),
        }
    }
}
