//! PJRT-backed [`Denoiser`]: the production request path.
//!
//! A thin, thread-safe facade over [`crate::runtime::RuntimeHandle`]; the
//! heavy lifting (variant selection, padding, execution) happens on the
//! executor thread.
//!
//! [`crate::model::KernelPrecision`] does not reach this backend: the
//! artifact's numerics are fixed at compile time, so a fast-tier request
//! served by PJRT simply runs the artifact as-is (the scratch's precision
//! field is ignored here — only the native oracle dispatches on it).

use crate::model::kernel::{KernelScratch, MaskRef};
use crate::model::{Denoiser, EvalOut};
use crate::runtime::RuntimeHandle;
use crate::Result;

/// Handle-based denoiser for one dataset.
pub struct PjrtDenoiser {
    handle: RuntimeHandle,
    dataset: String,
    dim: usize,
    k: usize,
}

impl PjrtDenoiser {
    pub fn new(handle: RuntimeHandle, dataset: &str, dim: usize, k: usize) -> PjrtDenoiser {
        PjrtDenoiser { handle, dataset: dataset.to_string(), dim, k }
    }
}

impl Denoiser for PjrtDenoiser {
    fn dim(&self) -> usize {
        self.dim
    }

    fn k(&self) -> usize {
        self.k
    }

    fn backend(&self) -> &'static str {
        "pjrt"
    }

    fn denoise_v(
        &self,
        xhat: &[f32],
        sigma: &[f32],
        a: &[f32],
        b: &[f32],
        mask: &[f32],
    ) -> Result<EvalOut> {
        self.handle.eval(
            &self.dataset,
            sigma.len(),
            xhat.to_vec(),
            sigma.to_vec(),
            a.to_vec(),
            b.to_vec(),
            mask.to_vec(),
        )
    }

    /// The executor thread needs owned buffers anyway, so the uniform
    /// path builds the broadcast vectors directly from the scalars —
    /// one staging pass fewer than the default impl (no scratch copy
    /// followed by a `to_vec`), with identical payload bits on the wire.
    fn denoise_v_uniform_into(
        &self,
        xhat: &[f32],
        rows: usize,
        sigma: f32,
        a: f32,
        b: f32,
        mask: MaskRef<'_>,
        out: &mut EvalOut,
        _scratch: &mut KernelScratch,
    ) -> Result<()> {
        mask.validate(rows, self.k)?;
        let mask_full = match mask {
            MaskRef::Full(m) => m.to_vec(),
            MaskRef::Row(m) => {
                let mut full = Vec::with_capacity(rows * m.len());
                for _ in 0..rows {
                    full.extend_from_slice(m);
                }
                full
            }
        };
        *out = self.handle.eval(
            &self.dataset,
            rows,
            xhat.to_vec(),
            vec![sigma; rows],
            vec![a; rows],
            vec![b; rows],
            mask_full,
        )?;
        Ok(())
    }
}
