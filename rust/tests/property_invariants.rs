//! Cross-module property tests (testutil::prop — the proptest substitute):
//! NFE accounting, schedule/resampling invariants, batcher conservation.

use std::sync::{mpsc, Arc};
use std::time::Duration;

use sdm::coordinator::batcher::{batcher_loop, BatchPolicy, Pending};
use sdm::coordinator::hub::EngineHub;
use sdm::coordinator::metrics::ServerMetrics;
use sdm::coordinator::protocol::{Request, Response, SampleRequest};
use sdm::coordinator::qos::{DrrScheduler, Inbox};
use sdm::diffusion::{CurvatureClock, Param};
use sdm::model::gmm::testmodel::toy;
use sdm::sampler::{run_sampler, RunConfig};
use sdm::schedule::baselines::edm_schedule;
use sdm::solvers::{LambdaKind, SolverSpec};
use sdm::testutil::prop::{forall_cfg, Gen, Pair, PropConfig, UsizeIn};
use sdm::util::{Rng, ThreadPool};

struct ParamGen;

impl Gen for ParamGen {
    type Value = &'static str;

    fn generate(&self, rng: &mut Rng) -> &'static str {
        ["edm", "vp", "ve"][rng.below(3)]
    }
}

fn cfg(cases: usize) -> PropConfig {
    PropConfig { cases, ..Default::default() }
}

#[test]
fn nfe_accounting_invariants() {
    // Euler: NFE == intervals; Heun: 2·intervals − 1; adaptive step-Λ:
    // intervals ≤ NFE ≤ 2·intervals − 1, for every (steps, param).
    let m = toy();
    let info = m.info.clone();
    forall_cfg(cfg(24), &Pair(UsizeIn(3, 24), ParamGen), |&(steps, pname)| {
        let param = Param::from_name(pname).unwrap();
        let grid =
            edm_schedule(steps, info.sigma_min, info.sigma_max, info.rho).map_err(|e| e.to_string())?;
        let run_cfg = RunConfig { rows: 8, seed: steps as u64, class: None, trace: false };
        let n = grid.intervals();
        let e = run_sampler(&m, param, &grid, &SolverSpec::Euler, &info, &run_cfg)
            .map_err(|e| e.to_string())?;
        if e.nfe != n {
            return Err(format!("euler nfe {} != intervals {n}", e.nfe));
        }
        let h = run_sampler(&m, param, &grid, &SolverSpec::Heun, &info, &run_cfg)
            .map_err(|e| e.to_string())?;
        if h.nfe != 2 * n - 1 {
            return Err(format!("heun nfe {} != {}", h.nfe, 2 * n - 1));
        }
        let solver = SolverSpec::Adaptive {
            lambda: LambdaKind::Step,
            tau_k: 5e-2,
            clock: CurvatureClock::Sigma,
        };
        let a = run_sampler(&m, param, &grid, &solver, &info, &run_cfg)
            .map_err(|e| e.to_string())?;
        if a.nfe < n || a.nfe > 2 * n - 1 {
            return Err(format!("adaptive nfe {} outside [{n}, {}]", a.nfe, 2 * n - 1));
        }
        Ok(())
    });
}

#[test]
fn samples_always_finite_across_design_space() {
    let m = toy();
    let info = m.info.clone();
    forall_cfg(cfg(20), &Pair(UsizeIn(4, 32), UsizeIn(0, 2)), |&(steps, pidx)| {
        let param = [Param::Edm, Param::vp(), Param::Ve][pidx];
        let grid =
            edm_schedule(steps, info.sigma_min, info.sigma_max, info.rho).map_err(|e| e.to_string())?;
        for solver in [
            SolverSpec::Euler,
            SolverSpec::Heun,
            SolverSpec::Adaptive {
                lambda: LambdaKind::Cosine,
                tau_k: 0.0,
                clock: CurvatureClock::Sigma,
            },
        ] {
            let run_cfg = RunConfig { rows: 4, seed: 99, class: None, trace: true };
            let out = run_sampler(&m, param, &grid, &solver, &info, &run_cfg)
                .map_err(|e| e.to_string())?;
            if !out.samples.iter().all(|v| v.is_finite()) {
                return Err(format!("non-finite samples: {} {:?}", param.name(), solver));
            }
            if out.steps.len() != grid.intervals() {
                return Err("trace length mismatch".into());
            }
        }
        Ok(())
    });
}

fn mk_request(n: usize, seed: u64) -> SampleRequest {
    let line = format!(
        r#"{{"op":"sample","dataset":"toy","n":{n},"solver":"euler","steps":5,"seed":{seed},"return_samples":true}}"#
    );
    match Request::parse(&line).unwrap() {
        Request::Sample(s) => s,
        _ => unreachable!(),
    }
}

#[test]
fn batcher_conserves_requests_under_random_load() {
    // every submitted request gets exactly one reply with exactly its rows,
    // regardless of arrival pattern or group composition.
    forall_cfg(cfg(12), &UsizeIn(1, 24), |&n_requests| {
        let hub = Arc::new(EngineHub::from_infos(vec![toy().info]));
        let metrics = Arc::new(ServerMetrics::new());
        let pool = Arc::new(ThreadPool::new(4));
        let sched = DrrScheduler::new(pool, 0, 256);
        let inbox = Arc::new(Inbox::new(0));
        let inbox2 = inbox.clone();
        let m2 = metrics.clone();
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let handle = std::thread::spawn(move || {
            batcher_loop("toy".into(), hub, m2, inbox2, BatchPolicy::default(), sched, stop)
        });
        let mut rng = Rng::new(n_requests as u64);
        let mut expected = Vec::new();
        let mut receivers = Vec::new();
        for i in 0..n_requests {
            let rows = 1 + rng.below(9);
            expected.push(rows);
            let (rtx, rrx) = mpsc::channel();
            inbox
                .try_push(Pending::new(mk_request(rows, i as u64), rtx))
                .map_err(|_| "push rejected")
                .unwrap();
            receivers.push(rrx);
        }
        inbox.close();
        for (rrx, rows) in receivers.iter().zip(&expected) {
            match rrx.recv_timeout(Duration::from_secs(30)) {
                Ok(Response::SampleOk { n, samples, dim, .. }) => {
                    if n != *rows {
                        return Err(format!("rows {n} != requested {rows}"));
                    }
                    if samples.unwrap().len() != rows * dim {
                        return Err("sample slice length mismatch".into());
                    }
                }
                other => return Err(format!("bad reply: {other:?}")),
            }
        }
        handle.join().unwrap();
        Ok(())
    });
}

#[test]
fn resampling_preserves_interval_count_for_any_source() {
    // random measured-eta vectors on random geometric grids never break
    // the resampler's contract (n+1 knots, exact endpoints, strict order).
    forall_cfg(
        cfg(64),
        &Pair(UsizeIn(8, 128), UsizeIn(2, 48)),
        |&(src_n, out_n)| {
            let grid = sdm::schedule::baselines::logsnr_schedule(src_n, 0.002, 80.0)
                .map_err(|e| e.to_string())?;
            let mut rng = Rng::new((src_n * 1000 + out_n) as u64);
            let eta: Vec<f64> = (0..grid.intervals()).map(|_| rng.uniform() + 1e-6).collect();
            let q = rng.uniform();
            let g = sdm::schedule::resample_n_steps(&grid.sigmas, &eta, out_n, q, 80.0)
                .map_err(|e| e.to_string())?;
            if g.sigmas.len() != out_n + 1 {
                return Err(format!("knots {} != {}", g.sigmas.len(), out_n + 1));
            }
            if (g.sigmas[0] - 80.0).abs() > 1e-9 {
                return Err("sigma_max endpoint".into());
            }
            Ok(())
        },
    );
}
