//! Schedule explorer: build every schedule family for one workload and
//! print the sigma grids side by side, plus the Algorithm-1 trace (eta_i,
//! S_hat_i) that drives the SDM schedule — the fastest way to *see* the
//! paper's Section 3.2 at work.
//!
//! ```bash
//! cargo run --release --example schedule_explorer -- [dataset] [steps]
//! ```

use std::sync::Arc;

use sdm::coordinator::{EngineHub, ModelBackend};
use sdm::diffusion::Param;
use sdm::model::datasets::artifact_dir;
use sdm::schedule::{wasserstein_schedule, ScheduleSpec, WassersteinConfig};
use sdm::util::Rng;

fn main() -> sdm::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args.first().cloned().unwrap_or_else(|| "cifar10g".into());
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(18);

    let hub = Arc::new(EngineHub::load(&artifact_dir(None), ModelBackend::Native)?);
    let info = hub.info(&dataset)?.clone();
    let param = Param::Edm;

    let families: Vec<(&str, ScheduleSpec)> = vec![
        ("edm(rho=7)", ScheduleSpec::Edm { rho: 7.0 }),
        ("linear", ScheduleSpec::LinearSigma),
        ("cosine", ScheduleSpec::Cosine),
        ("logsnr", ScheduleSpec::LogSnr),
        ("cos", ScheduleSpec::Cos { pilot_mult: 4, pilot_rows: 128 }),
        ("sdm", ScheduleSpec::sdm_defaults(&dataset, param)),
    ];
    let mut grids = Vec::new();
    for (name, spec) in &families {
        grids.push((name, hub.schedule(&dataset, param, spec, steps)?));
    }
    println!("sigma grids for {dataset} ({steps} steps):");
    print!("{:>4}", "i");
    for (name, _) in &grids {
        print!(" {:>12}", name);
    }
    println!();
    for i in 0..=steps {
        print!("{i:>4}");
        for (_, g) in &grids {
            print!(" {:>12.5}", g.sigmas[i]);
        }
        println!();
    }

    // Algorithm 1 raw trace before resampling
    let model = hub.model(&dataset)?;
    let mut rng = Rng::new(7);
    let out = wasserstein_schedule(&info, param, model.as_ref(), &mut rng,
        &WassersteinConfig::default(), 64)?;
    println!("\nAlgorithm 1 raw schedule: {} knots, pilot NFE {}", out.sigmas.len(), out.pilot_nfe);
    println!("{:>4} {:>12} {:>14} {:>14}", "i", "sigma", "eta_i", "S_hat_i");
    for i in 0..out.eta.len().min(50) {
        println!("{:>4} {:>12.5} {:>14.6e} {:>14.6e}", i, out.sigmas[i], out.eta[i], out.s_hat[i]);
    }
    Ok(())
}
