//! Quality-vs-NFE Pareto frontier (the paper's §1 claim: SDM improves the
//! Pareto frontier of quality versus efficiency for pre-trained models).
//!
//! Sweeps the step budget for each (solver, schedule) family and reports
//! (NFE, FD) series; "who dominates where" is the reproduction target.

use crate::diffusion::{CurvatureClock, Param};
use crate::experiments::{evaluate_all, ExpContext};
use crate::sampler::SamplerConfig;
use crate::schedule::ScheduleSpec;
use crate::solvers::{LambdaKind, SolverSpec};
use crate::Result;

/// One frontier point.
#[derive(Clone, Debug)]
pub struct ParetoPoint {
    pub family: String,
    pub steps: usize,
    pub nfe: f64,
    pub fd: f64,
}

pub fn run(
    ctx: &ExpContext,
    dataset: &str,
    param: Param,
    budgets: &[usize],
) -> Result<Vec<ParetoPoint>> {
    let tau_k = match SolverSpec::sdm_default(dataset, false, matches!(param, Param::Vp { .. })) {
        SolverSpec::Adaptive { tau_k, .. } => tau_k,
        _ => unreachable!(),
    };
    let families: Vec<(&str, SolverSpec, ScheduleSpec)> = vec![
        ("euler+edm", SolverSpec::Euler, ScheduleSpec::Edm { rho: 7.0 }),
        ("heun+edm", SolverSpec::Heun, ScheduleSpec::Edm { rho: 7.0 }),
        ("heun+cos", SolverSpec::Heun, ScheduleSpec::Cos { pilot_mult: 4, pilot_rows: 128 }),
        (
            "sdm+edm",
            SolverSpec::Adaptive { lambda: LambdaKind::Step, tau_k, clock: CurvatureClock::Sigma },
            ScheduleSpec::Edm { rho: 7.0 },
        ),
        (
            "sdm+sdm",
            SolverSpec::Adaptive { lambda: LambdaKind::Step, tau_k, clock: CurvatureClock::Sigma },
            ScheduleSpec::sdm_defaults(dataset, param),
        ),
    ];

    let mut cfgs = Vec::new();
    let mut meta = Vec::new();
    for (name, solver, schedule) in &families {
        for &steps in budgets {
            cfgs.push(SamplerConfig {
                dataset: dataset.to_string(),
                param,
                solver: *solver,
                schedule: schedule.clone(),
                steps,
                class: None,
            });
            meta.push((name.to_string(), steps));
        }
    }
    let results = evaluate_all(ctx, cfgs);
    println!("Pareto frontier — {dataset} ({})", param.name());
    println!("{:<12} {:>6} {:>8} {:>10}", "family", "steps", "NFE", "FD");
    let mut out = Vec::new();
    for ((family, steps), r) in meta.into_iter().zip(results) {
        let r = r?;
        println!("{:<12} {:>6} {:>8.1} {:>10.4}", family, steps, r.nfe, r.fd);
        out.push(ParetoPoint { family, steps, nfe: r.nfe, fd: r.fd });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::EngineHub;
    use crate::model::gmm::testmodel::toy;
    use std::sync::Arc;

    #[test]
    fn frontier_shapes() {
        let hub = Arc::new(EngineHub::from_infos(vec![toy().info]));
        let ctx = ExpContext { samples: 2048, rows: 256, seed: 5, threads: 4, hub, pool: None };
        let pts = run(&ctx, "toy", Param::Edm, &[8, 16]).unwrap();
        assert_eq!(pts.len(), 10);
        // more steps should not hurt quality within a family (weak check:
        // euler family strictly improves from 8 to 16 steps)
        let e8 = pts.iter().find(|p| p.family == "euler+edm" && p.steps == 8).unwrap();
        let e16 = pts.iter().find(|p| p.family == "euler+edm" && p.steps == 16).unwrap();
        assert!(e16.fd < e8.fd, "euler 16-step {e16:?} vs 8-step {e8:?}");
        // heun at equal steps costs more NFE than euler
        let h8 = pts.iter().find(|p| p.family == "heun+edm" && p.steps == 8).unwrap();
        assert!(h8.nfe > e8.nfe);
    }
}
