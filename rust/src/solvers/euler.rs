//! First-order Euler step: x' = x + Δt·v. O(h²) local truncation error,
//! 1 NFE per interval — the efficient choice in the near-linear high-noise
//! regime (paper §3.1).

/// In-place Euler update over a flat [rows·dim] state.
// lint: no-alloc
pub fn euler_step(x: &mut [f32], v: &[f32], dt: f64) {
    debug_assert_eq!(x.len(), v.len());
    let dt = dt as f32;
    for (xv, vv) in x.iter_mut().zip(v) {
        *xv += dt * vv;
    }
}

/// Out-of-place Euler step (used for trial/predictor states).
pub fn euler_step_to(x: &[f32], v: &[f32], dt: f64, out: &mut Vec<f32>) {
    debug_assert_eq!(x.len(), v.len());
    out.clear();
    out.reserve(x.len());
    let dt = dt as f32;
    out.extend(x.iter().zip(v).map(|(xv, vv)| xv + dt * vv));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn euler_on_linear_field_is_exact() {
        // v = const ⇒ Euler exact
        let mut x = vec![1.0f32, -2.0];
        euler_step(&mut x, &[0.5, 1.0], 2.0);
        assert_eq!(x, vec![2.0, 0.0]);
    }

    #[test]
    fn out_of_place_matches_in_place() {
        let x = vec![0.3f32, 0.7, -0.1];
        let v = vec![1.0f32, -1.0, 2.0];
        let mut out = Vec::new();
        euler_step_to(&x, &v, -0.25, &mut out);
        let mut x2 = x.clone();
        euler_step(&mut x2, &v, -0.25);
        assert_eq!(out, x2);
    }
}
