//! End-to-end serving driver (DESIGN.md "End-to-end validation"): starts
//! the TCP coordinator on the PJRT backend, drives concurrent batched
//! sample requests through the full router -> batcher -> PJRT-executor
//! stack, and reports latency/throughput plus server-side metrics.
//!
//! ```bash
//! cargo run --release --example serve_and_query
//! ```

use std::sync::Arc;

use sdm::coordinator::{Client, EngineHub, ModelBackend, Server, ServerConfig};
use sdm::model::datasets::artifact_dir;
use sdm::util::{Histogram, Json, Timer};

fn main() -> sdm::Result<()> {
    let backend = if std::env::args().any(|a| a == "--native") {
        ModelBackend::Native
    } else {
        ModelBackend::Pjrt
    };
    let hub = Arc::new(EngineHub::load(&artifact_dir(None), backend)?);
    let server = Server::start(hub, ServerConfig::default())?;
    let addr = server.local_addr.to_string();
    println!("serving on {addr} (backend {backend:?})");

    // warm the schedule caches (first SDM request pays Algorithm 1)
    let mut warm = Client::connect(&addr)?;
    warm.sample("cifar10g", 16, "vp", "sdm", "sdm", 18, 0)?;

    let concurrency = 8;
    let per_client = 24;
    let timer = Timer::start();
    let mut handles = Vec::new();
    for tid in 0..concurrency {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || -> sdm::Result<Histogram> {
            let mut client = Client::connect(&addr)?;
            let mut hist = Histogram::new();
            for i in 0..per_client {
                let t = Timer::start();
                // mix of solvers and datasets, like real traffic
                let (ds, solver) = match (tid + i) % 3 {
                    0 => ("cifar10g", "sdm"),
                    1 => ("cifar10g", "heun"),
                    _ => ("afhqg", "sdm"),
                };
                let steps = if ds == "cifar10g" { 18 } else { 40 };
                let resp = client.sample(ds, 32, "vp", solver, "edm", steps, (tid * 100 + i) as u64)?;
                anyhow::ensure!(resp.get("ok")? == &Json::Bool(true), "{resp:?}");
                hist.record(t.elapsed_us());
            }
            Ok(hist)
        }));
    }
    let mut all = Histogram::new();
    for h in handles {
        all.merge(&h.join().unwrap()?);
    }
    let wall = timer.elapsed_us() / 1e6;
    println!("client view : {}", all.summary("us"));
    println!(
        "throughput  : {:.1} req/s ({:.0} samples/s)",
        all.count() as f64 / wall,
        all.count() as f64 * 32.0 / wall
    );

    let stats = warm.send(r#"{"op":"stats"}"#)?;
    println!("server stats: {}", stats.get("stats")?.to_string());
    warm.shutdown_server()?;
    server.shutdown();
    Ok(())
}
