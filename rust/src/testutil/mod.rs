//! Test-only substrates, including the miniature property-testing
//! framework standing in for `proptest` (absent from the vendored crate
//! set — DESIGN.md §2). Exposed as a normal module so integration tests
//! and examples can use it too.

pub mod prop;

pub use prop::{forall, forall_cfg, Gen, PropConfig};
