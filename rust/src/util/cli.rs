//! Tiny CLI argument substrate (no `clap` in the vendored crate set).
//!
//! Supports the subcommand + `--key value` / `--flag` grammar used by the
//! `sdm` binary and the examples. Unknown flags are an error (typo safety),
//! and every flag lookup records itself so `finish()` can report unused
//! arguments.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed command line: one optional subcommand, flags, free args.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: Option<String>,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
    pub positional: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.command = it.next();
            }
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    // `--` terminator: rest are positional
                    out.positional.extend(it.by_ref());
                    break;
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.bools.push(name.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    /// String flag with default.
    pub fn get(&self, key: &str, default: &str) -> String {
        self.mark(key);
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string flag.
    pub fn opt(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.flags.get(key).cloned()
    }

    /// Required string flag.
    pub fn require(&self, key: &str) -> Result<String> {
        self.mark(key);
        match self.flags.get(key) {
            Some(v) => Ok(v.clone()),
            None => bail!("missing required flag --{key}"),
        }
    }

    /// Numeric flag with default.
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        self.mark(key);
        match self.flags.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        self.mark(key);
        match self.flags.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        self.mark(key);
        match self.flags.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    /// Boolean switch (`--flag`).
    pub fn has(&self, key: &str) -> bool {
        self.mark(key);
        self.bools.iter().any(|b| b == key)
    }

    /// Error on any flag that was provided but never consumed by the
    /// subcommand — catches typos like `--stpes 18`.
    pub fn finish(&self) -> Result<()> {
        let seen = self.consumed.borrow();
        for k in self.flags.keys() {
            if !seen.iter().any(|s| s == k) {
                bail!("unknown flag --{k}");
            }
        }
        for k in &self.bools {
            if !seen.iter().any(|s| s == k) {
                bail!("unknown switch --{k}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("table1 --steps 18 --dataset cifar10g --verbose");
        assert_eq!(a.command.as_deref(), Some("table1"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 18);
        assert_eq!(a.get("dataset", ""), "cifar10g");
        assert!(a.has("verbose"));
        a.finish().unwrap();
    }

    #[test]
    fn equals_syntax() {
        let a = parse("run --eta-max=0.4");
        assert_eq!(a.get_f64("eta-max", 0.0).unwrap(), 0.4);
        a.finish().unwrap();
    }

    #[test]
    fn unknown_flag_rejected() {
        let a = parse("run --stpes 18");
        let _ = a.get_usize("steps", 0);
        assert!(a.finish().is_err());
    }

    #[test]
    fn missing_required() {
        let a = parse("serve");
        assert!(a.require("port").is_err());
    }

    #[test]
    fn bool_flag_before_another_flag() {
        let a = parse("x --dry-run --n 3");
        assert!(a.has("dry-run"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 3);
        a.finish().unwrap();
    }

    #[test]
    fn positional_after_terminator() {
        let a = parse("x --v 1 -- a b");
        assert_eq!(a.get("v", ""), "1");
        assert_eq!(a.positional, vec!["a", "b"]);
    }
}
