//! Qualitative comparison dumps (Figures 5–9 substitute).
//!
//! The paper shows image grids; our workloads are point clouds, so the
//! qualitative artifact is a TSV of generated samples (first two
//! coordinates) per configuration, next to a ground-truth draw — plottable
//! as the scatter-grid analogue of the paper's panels. FD/NFE captions are
//! printed exactly like the figure captions.

use std::io::Write;
use std::path::Path;

use crate::diffusion::Param;
use crate::experiments::{evaluate, ExpContext};
use crate::sampler::SamplerConfig;
use crate::schedule::ScheduleSpec;
use crate::solvers::SolverSpec;
use crate::Result;

/// The four panels of each qualitative figure: EDM(Heun), SDM(solver),
/// SDM(scheduling), SDM(solver+scheduling).
pub fn panels(dataset: &str, param: Param, steps: usize) -> Vec<(String, SamplerConfig)> {
    let is_vp = matches!(param, Param::Vp { .. });
    let base = SamplerConfig {
        dataset: dataset.to_string(),
        param,
        plan: SolverSpec::Heun.into(),
        schedule: ScheduleSpec::Edm { rho: 7.0 },
        steps,
        class: None,
    };
    vec![
        ("edm_heun".into(), base.clone()),
        (
            "sdm_solver".into(),
            SamplerConfig {
                plan: SolverSpec::sdm_default(dataset, is_vp).into(),
                ..base.clone()
            },
        ),
        (
            "sdm_sched".into(),
            SamplerConfig { schedule: ScheduleSpec::sdm_defaults(dataset, param), ..base.clone() },
        ),
        (
            "sdm_both".into(),
            SamplerConfig {
                plan: SolverSpec::sdm_default(dataset, is_vp).into(),
                schedule: ScheduleSpec::sdm_defaults(dataset, param),
                ..base
            },
        ),
    ]
}

/// Generate the panel dumps for one dataset/param into `out_dir`.
pub fn run(ctx: &ExpContext, dataset: &str, param: Param, out_dir: &Path) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let info = ctx.hub.info(dataset)?.clone();
    let steps = info.default_steps;
    let oracle = ctx.hub.oracle(dataset)?;

    // ground-truth panel
    let mut rng = crate::util::Rng::new(ctx.seed ^ 0x9A11);
    let truth = oracle.sample_data(&mut rng, 512, None);
    dump(
        &out_dir.join(format!("{dataset}_{}_truth.tsv", param.name())),
        &truth.iter().map(|&v| v as f32).collect::<Vec<f32>>(),
        info.dim,
    )?;

    println!("Qualitative panels — {dataset} ({}) [paper Figs. 5-9]", param.name());
    for (name, cfg) in panels(dataset, param, steps) {
        let small_ctx = ExpContext { samples: 512, ..ctx.clone() };
        let row = evaluate(&small_ctx, &cfg)?;
        // regenerate the exact samples for the dump (same seed path)
        let model = ctx.hub.model(dataset)?;
        let grid = ctx.hub.schedule_for_plan(
            dataset,
            cfg.param,
            &cfg.schedule,
            cfg.steps,
            &cfg.plan.cache_tag(),
        )?;
        let run_cfg = crate::sampler::RunConfig {
            rows: 256,
            seed: ctx.seed ^ crate::experiments::fxhash(&cfg.label()),
            class: None,
            trace: false,
        };
        let (samples, _, _, _) = crate::sampler::engine::generate_plan(
            model.as_ref(),
            cfg.param,
            &grid,
            &cfg.plan,
            &info,
            &run_cfg,
            512,
        )?;
        let path = out_dir.join(format!("{dataset}_{}_{name}.tsv", param.name()));
        dump(&path, &samples, info.dim)?;
        println!("  {name:<12} FD={:.4} NFE={:.1} -> {}", row.fd, row.nfe, path.display());
    }
    Ok(())
}

fn dump(path: &Path, samples: &[f32], dim: usize) -> Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "x0\tx1")?;
    for row in samples.chunks(dim) {
        writeln!(f, "{}\t{}", row[0], row.get(1).copied().unwrap_or(0.0))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::EngineHub;
    use crate::model::gmm::testmodel::toy;
    use std::sync::Arc;

    #[test]
    fn four_panels_match_paper_layout() {
        let p = panels("toy", Param::Edm, 12);
        assert_eq!(p.len(), 4);
        assert!(matches!(p[0].1.plan.solo(), Some(SolverSpec::Heun)));
        assert!(matches!(p[3].1.plan.solo(), Some(SolverSpec::Adaptive { .. })));
        assert!(matches!(p[3].1.schedule, ScheduleSpec::Sdm { .. }));
    }

    #[test]
    fn run_writes_tsvs() {
        let hub = Arc::new(EngineHub::from_infos(vec![toy().info]));
        let ctx = ExpContext {
            samples: 512,
            rows: 256,
            seed: 3,
            threads: 2,
            hub,
            pool: None,
            precision: Default::default(),
        };
        let dir = std::env::temp_dir().join("sdm_qualitative_test");
        run(&ctx, "toy", Param::Edm, &dir).unwrap();
        assert!(dir.join("toy_edm_truth.tsv").exists());
        assert!(dir.join("toy_edm_sdm_both.tsv").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
