//! Pilot rollout: measure the local-error structure of a fixed σ grid.
//!
//! Runs a small Euler batch down the trajectory once and records, per
//! interval i, the velocity-variation estimate Ŝ_i (eq. 13, evaluated
//! along the sampling trajectory) and the induced local Wasserstein error
//! proxy η̂_i = Δt_i²/2 · Ŝ_i (Thm 3.2 inverted). These measurements feed
//! the COS baseline, the N-step resampler, Figure 2 (κ̂ vs σ), and
//! Figure 3 (η_t profiles).

use crate::diffusion::{kappa_hat_rel, CurvatureClock, CurvaturePoint, Param, SigmaGrid};
use crate::model::{eval_at_into, uncond_mask_row, Denoiser, EvalScratch, MaskRef};
use crate::util::Rng;
use crate::Result;

/// Per-interval measurements along a pilot rollout.
#[derive(Clone, Debug)]
pub struct PilotMeasurement {
    /// σ knots of the measured grid (len = intervals + 1).
    pub sigmas: Vec<f64>,
    /// native times (same length).
    pub times: Vec<f64>,
    /// Ŝ_i per interval (eq. 13); last interval extrapolated.
    pub s_hat: Vec<f64>,
    /// η̂_i = Δt_i²/2 · Ŝ_i per interval.
    pub eta: Vec<f64>,
    /// cache-based curvature κ̂ (σ clock) per interior knot, for Figure 2.
    pub kappa: Vec<CurvaturePoint>,
}

/// Euler pilot over `grid` with `rows` rows (NFE = intervals; build-time
/// only — never on the request path).
pub fn pilot_measure(
    ds_dim: usize,
    ds_k: usize,
    grid: &SigmaGrid,
    param: Param,
    model: &dyn Denoiser,
    rng: &mut Rng,
    rows: usize,
) -> Result<PilotMeasurement> {
    let times = grid.times(param);
    let sigmas = grid.sigmas.clone();
    let intervals = grid.intervals();
    anyhow::ensure!(rows > 0, "pilot rows");

    let mask_row = uncond_mask_row(ds_k);
    let mask = MaskRef::Row(&mask_row);
    let mut x = vec![0.0f32; rows * ds_dim];
    rng.fill_normal_f32(&mut x, param.prior_std(times[0]));

    let mut s_hat = Vec::with_capacity(intervals);
    let mut eta = Vec::with_capacity(intervals);
    let mut kappa = Vec::new();

    // velocities double-buffered in the arena: cur = v_i, prev = v_{i−1}
    let mut scr = EvalScratch::new();
    let mut have_prev = false;
    let mut prev_t = times[0];
    let mut prev_sig = sigmas[0];

    for i in 0..intervals {
        let (t_i, t_next) = (times[i], times[i + 1]);
        eval_at_into(model, param, &x, t_i, mask, rows, &mut scr.xhat, &mut scr.kernel, &mut scr.cur)?;
        if have_prev {
            // Ŝ for the *previous* interval: ‖v_i − v_{i−1}‖ / Δt_{i−1}
            let dt_prev = prev_t - t_i;
            let s = mean_dv_norm(&scr.prev.v, &scr.cur.v, rows, ds_dim) / dt_prev.max(1e-30);
            s_hat.push(s);
            eta.push(0.5 * dt_prev * dt_prev * s);
            let dsig = CurvatureClock::Sigma.delta(prev_t, t_i, prev_sig, sigmas[i]);
            kappa.push(CurvaturePoint {
                sigma: sigmas[i],
                kappa_hat: kappa_hat_rel(&scr.prev.v, &scr.cur.v, rows, ds_dim, dsig),
            });
        }
        // Euler commit
        let dt = (t_next - t_i) as f32;
        for (xv, vv) in x.iter_mut().zip(&scr.cur.v) {
            *xv += dt * vv;
        }
        std::mem::swap(&mut scr.prev, &mut scr.cur);
        have_prev = true;
        prev_t = t_i;
        prev_sig = sigmas[i];
    }
    // the final interval (σ→0) cannot be measured (velocity singular at
    // σ=0); extrapolate with the last observed Ŝ
    let last_s = s_hat.last().copied().unwrap_or(0.0);
    let dt_last = times[intervals - 1] - times[intervals];
    s_hat.push(last_s);
    eta.push(0.5 * dt_last * dt_last * last_s);
    debug_assert_eq!(s_hat.len(), intervals);

    Ok(PilotMeasurement { sigmas, times, s_hat, eta, kappa })
}

fn mean_dv_norm(v_prev: &[f32], v_cur: &[f32], rows: usize, dim: usize) -> f64 {
    let mut total = 0.0f64;
    for r in 0..rows {
        let mut dv2 = 0.0f64;
        for c in 0..dim {
            let d = (v_cur[r * dim + c] - v_prev[r * dim + c]) as f64;
            dv2 += d * d;
        }
        total += dv2.sqrt();
    }
    total / rows as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gmm::testmodel::toy;
    use crate::schedule::baselines::edm_schedule;

    #[test]
    fn pilot_shapes_and_positivity() {
        let m = toy();
        let grid = edm_schedule(12, 0.002, 80.0, 7.0).unwrap();
        let mut rng = Rng::new(3);
        let pm = pilot_measure(3, 2, &grid, Param::Edm, &m, &mut rng, 32).unwrap();
        assert_eq!(pm.eta.len(), grid.intervals());
        assert_eq!(pm.s_hat.len(), grid.intervals());
        assert_eq!(pm.kappa.len(), grid.intervals() - 1);
        assert!(pm.eta.iter().all(|&e| e.is_finite() && e >= 0.0));
        assert!(pm.s_hat.iter().all(|&s| s.is_finite() && s >= 0.0));
    }

    #[test]
    fn curvature_grows_toward_low_sigma() {
        // Figure 2's qualitative shape: κ̂ correlates inversely with σ.
        let m = toy();
        let grid = edm_schedule(24, 0.002, 80.0, 7.0).unwrap();
        let mut rng = Rng::new(4);
        let pm = pilot_measure(3, 2, &grid, Param::Edm, &m, &mut rng, 64).unwrap();
        let hi_sigma_kappa = pm.kappa.first().unwrap().kappa_hat;
        let lo_sigma_kappa = pm.kappa[pm.kappa.len() - 3].kappa_hat;
        assert!(
            lo_sigma_kappa > 5.0 * hi_sigma_kappa,
            "low-sigma κ̂ {lo_sigma_kappa} vs high-sigma {hi_sigma_kappa}"
        );
    }

    #[test]
    fn works_for_all_parameterizations() {
        let m = toy();
        let grid = edm_schedule(10, 0.002, 80.0, 7.0).unwrap();
        for p in [Param::Edm, Param::vp(), Param::Ve] {
            let mut rng = Rng::new(5);
            let pm = pilot_measure(3, 2, &grid, p, &m, &mut rng, 16).unwrap();
            assert!(pm.eta.iter().all(|e| e.is_finite()), "{:?}", p.name());
        }
    }
}
