//! `sdm` — leader binary: serving, sampling, and every paper experiment.
//!
//! ```text
//! sdm serve      --addr 127.0.0.1:7433 [--backend pjrt|native]
//!                [--inbox-depth N --qos-weight ds=w,... --qos-slots N]
//! sdm sample     --dataset cifar10g --n 64 --solver sdm --schedule sdm ...
//! sdm schedule   --dataset cifar10g --schedule sdm --steps 18
//! sdm table1|table4|table5|grid-tau|grid-eta|fig2|fig3|fig4|pareto|qualitative
//! sdm bench-client --addr ... --requests 256 --concurrency 8
//! sdm loadgen    --closed-loop --slo-p99-ms 100 [--addr ... | --in-process]
//! ```
//!
//! Experiments default to the PJRT backend (`--backend pjrt`) so the AOT
//! artifact path is exercised end to end; `--backend native` switches to
//! the closed-form oracle for fast wide sweeps (identical numerics, see
//! rust/tests/pjrt_integration.rs).

use std::sync::Arc;

use sdm::coordinator::{Client, EngineHub, ModelBackend, Server, ServerConfig};
use sdm::diffusion::Param;
use sdm::experiments::{self, ExpContext};
use sdm::model::datasets::artifact_dir;
use sdm::util::{Args, Histogram, Timer};
use sdm::Result;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Schedule-cache policy flags, shared by every hub-loading subcommand:
/// `--cache-capacity N` (0 = unbounded), `--cache-ttl-s SECS` (0 = never
/// expire), plus persistence / warm-start switches.
///
/// `default_on` selects the amortization stance. Serving (`sdm serve`)
/// defaults both persistence and warm start ON (opt out with
/// `--no-cache-persist` / `--no-warm-start`). Experiment and one-shot
/// subcommands default both OFF (opt in with `--cache-persist` /
/// `--warm-start`): paper-reproduction numbers must not depend on what
/// schedules an earlier run left in the artifact dir — warm-started
/// builds are deliberately order-dependent (DESIGN.md §5).
fn cache_config(
    args: &Args,
    artifact_dir: &std::path::Path,
    backend: ModelBackend,
    default_on: bool,
) -> Result<sdm::schedule::CacheConfig> {
    let mut cache = sdm::schedule::CacheConfig::default();
    cache.capacity = args.get_usize("cache-capacity", cache.capacity)?;
    let ttl_s = args.get_f64("cache-ttl-s", 0.0)?;
    if ttl_s > 0.0 {
        cache.ttl = Some(std::time::Duration::from_secs_f64(ttl_s));
    }
    // consume every switch in both modes so `finish()` accepts them
    let no_persist = args.has("no-cache-persist");
    let yes_persist = args.has("cache-persist");
    let no_warm = args.has("no-warm-start");
    let yes_warm = args.has("warm-start");
    let persist = if default_on { !no_persist } else { yes_persist && !no_persist };
    cache.persist_path = persist
        .then(|| artifact_dir.join(sdm::coordinator::hub::schedule_cache_file(backend)));
    cache.warm_start = if default_on { !no_warm } else { yes_warm && !no_warm };
    Ok(cache)
}

fn load_hub(args: &Args) -> Result<Arc<EngineHub>> {
    let dir = artifact_dir(args.opt("artifacts"));
    let backend = ModelBackend::from_name(&args.get("backend", "pjrt"))?;
    let cache = cache_config(args, &dir, backend, false)?;
    Ok(Arc::new(EngineHub::load_with(&dir, backend, cache)?))
}

fn exp_context(args: &Args) -> Result<ExpContext> {
    // --toy: artifact-free hub over the built-in toy + synth16x64
    // workloads (smoke runs in bare containers, e.g. the CI fast-kernel
    // leg); checked before load_hub so no artifact dir is required
    let hub = if args.has("toy") {
        Arc::new(EngineHub::from_infos(vec![
            sdm::model::gmm::testmodel::toy().info,
            sdm::model::gmm::testmodel::synthetic(16, 64).info,
        ]))
    } else {
        load_hub(args)?
    };
    let mut ctx = ExpContext::new(hub);
    ctx.samples = args.get_usize("samples", 8192)?;
    ctx.rows = args.get_usize("rows", 256)?;
    ctx.seed = args.get_u64("seed", 2026)?;
    ctx.threads = args.get_usize("threads", 8)?;
    // opt-in fast kernel tier (DESIGN.md §10); exact is the default and
    // stays bit-identical to the seed kernel
    ctx.precision =
        sdm::model::KernelPrecision::from_name(&args.get("kernel-precision", "exact"))?;
    // shared worker pool: config sweeps and row-sharded generation both
    // draw from it (identical numerics to the serial path)
    Ok(ctx.with_pool())
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    let cmd = args.command.clone().unwrap_or_else(|| "help".into());
    match cmd.as_str() {
        "serve" => serve(&args),
        "sample" => sample(&args),
        "schedule" => schedule(&args),
        "table1" => {
            let ctx = exp_context(&args)?;
            args.finish()?;
            experiments::table1::run(&ctx)?;
            Ok(())
        }
        "table4" => {
            let ctx = exp_context(&args)?;
            args.finish()?;
            experiments::table4::run(&ctx)?;
            Ok(())
        }
        "table5" => {
            let ctx = exp_context(&args)?;
            args.finish()?;
            experiments::table5::run(&ctx)?;
            Ok(())
        }
        "grid-tau" | "fig4" => {
            let ctx = exp_context(&args)?;
            let sched = args.get("schedule", "edm");
            args.finish()?;
            // Figure 4's curves: cifar10g + afhqg, uncond + cond (cifar)
            let sets: Vec<(&str, usize, Option<usize>)> = vec![
                ("cifar10g", 18, None),
                ("cifar10g", 18, Some(0)),
                ("afhqg", 40, None),
            ];
            experiments::grids::run_tau_sweep(&ctx, &sets, &sched)?;
            Ok(())
        }
        "grid-eta" => {
            let ctx = exp_context(&args)?;
            args.finish()?;
            experiments::grids::run_eta_grid(&ctx)?;
            Ok(())
        }
        "fig2" => {
            let ctx = exp_context(&args)?;
            let steps = args.get_usize("steps", 40)?;
            args.finish()?;
            experiments::figures::fig2(&ctx, steps)?;
            Ok(())
        }
        "fig3" => {
            let ctx = exp_context(&args)?;
            let ds = args.get("dataset", "imagenetg");
            args.finish()?;
            experiments::figures::fig3(&ctx, &ds)?;
            Ok(())
        }
        "pareto" => {
            // artifact-free CI smoke: toy dataset, one budget, every
            // family (static, segmented, PID) — checked before loading
            // any hub so it runs in bare containers
            if args.has("smoke") {
                let precision = sdm::model::KernelPrecision::from_name(
                    &args.get("kernel-precision", "exact"),
                )?;
                args.finish()?;
                experiments::pareto::smoke(precision)?;
                return Ok(());
            }
            let ctx = exp_context(&args)?;
            let ds = args.get("dataset", "cifar10g");
            let param = Param::from_name(&args.get("param", "vp"))?;
            args.finish()?;
            let budgets = [6, 9, 12, 18, 24, 32, 48];
            experiments::pareto::run(&ctx, &ds, param, &budgets)?;
            Ok(())
        }
        "qualitative" => {
            let ctx = exp_context(&args)?;
            let out = std::path::PathBuf::from(args.get("out", "qualitative_out"));
            args.finish()?;
            for ds in ["cifar10g", "ffhqg", "afhqg"] {
                for p in [Param::vp(), Param::Ve] {
                    experiments::qualitative::run(&ctx, ds, p, &out)?;
                }
            }
            experiments::qualitative::run(&ctx, "imagenetg", Param::Edm, &out)?;
            Ok(())
        }
        "ablate-clock" => {
            let ctx = exp_context(&args)?;
            let ds = args.get("dataset", "cifar10g");
            args.finish()?;
            experiments::ablations::run_clock_ablation(&ctx, &ds)?;
            Ok(())
        }
        "ablate-refgrid" => {
            let ctx = exp_context(&args)?;
            let ds = args.get("dataset", "cifar10g");
            args.finish()?;
            experiments::ablations::run_refgrid_ablation(&ctx, &ds)?;
            Ok(())
        }
        "bench-client" => bench_client(&args),
        "loadgen" => loadgen(&args),
        "analyze" => sdm::analyze::run_cli(&args),
        "bench-sampler" => {
            // same harness as `cargo bench --bench bench_sampler`; the CLI
            // binary has no counting allocator, so allocs/call is omitted
            let smoke = args.has("smoke");
            let out = args.get("out", "BENCH_sampler.json");
            let label = args.get("label", "cli");
            args.finish()?;
            sdm::perf::run_sampler_bench(&sdm::perf::BenchOptions {
                smoke,
                out_path: Some(std::path::PathBuf::from(out)),
                label,
            })?;
            Ok(())
        }
        _ => {
            print_help();
            Ok(())
        }
    }
}

/// QoS flags shared by `serve`: `--inbox-depth N` (0 = unbounded),
/// `--qos-weight ds=w,...` (DRR fairness weights), `--qos-slots N`
/// (global concurrent flushes; 0 = pool threads), `--qos-quantum ROWS`
/// (DRR row credit per round; 0 = max_batch), `--qos-retry-ms MS`
/// (back-off hint in QueueFull replies).
fn qos_policy(args: &Args) -> Result<sdm::coordinator::QosPolicy> {
    let mut qos = sdm::coordinator::QosPolicy::default();
    qos.inbox_depth = args.get_usize("inbox-depth", qos.inbox_depth)?;
    if let Some(spec) = args.opt("qos-weight") {
        qos.weights = sdm::coordinator::QosPolicy::parse_weights(&spec)?;
    }
    qos.flush_slots = args.get_usize("qos-slots", qos.flush_slots)?;
    qos.quantum_rows = args.get_usize("qos-quantum", qos.quantum_rows)?;
    qos.retry_after_ms = args.get_f64("qos-retry-ms", qos.retry_after_ms)?;
    Ok(qos)
}

fn serve(args: &Args) -> Result<()> {
    let dir = artifact_dir(args.opt("artifacts"));
    let backend = ModelBackend::from_name(&args.get("backend", "pjrt"))?;
    let addr = args.get("addr", "127.0.0.1:7433");
    // --http-addr starts the HTTP/SSE gateway (DESIGN.md §13) beside the
    // socket front-end; omit it and no listener (or per-step hook) exists
    let http_addr = args.opt("http-addr");
    // --toy: artifact-free hub over the built-in toy + synth16x64
    // workloads (CI smoke, local gateway demos)
    let toy_hub = args.has("toy");
    let pool_threads = args.get_usize("pool-threads", 0)?;
    let max_inflight = args.get_usize("max-inflight", 4)?;
    // native-oracle kernel evals row-shard across the worker pool from
    // this batch size up (0 disables sharding entirely)
    let shard_min_rows = args.get_usize("shard-min-rows", 512)?;
    // deterministic fault injection (DESIGN.md §12): a seeded plan like
    // "eval_err@1/200,eval_delay@p50=5ms,conn_drop@1/50" — OFF by
    // default; with no plan every chaos hook is a zero-cost no-op
    let chaos_spec = args.opt("chaos");
    let chaos_seed = args.get_u64("chaos-seed", 42)?;
    let mut cache = cache_config(args, &dir, backend, true)?;
    let qos = qos_policy(args)?;
    args.finish()?;
    let chaos = match &chaos_spec {
        Some(spec) => Some(Arc::new(sdm::chaos::FaultPlan::parse(spec, chaos_seed)?)),
        None => None,
    };
    cache.chaos = chaos.clone();
    let mut cfg = ServerConfig { addr: addr.clone(), pool_threads, qos, ..Default::default() };
    cfg.policy.max_inflight = max_inflight;
    cfg.chaos = chaos.clone();
    cfg.http_addr = http_addr.clone();
    let pool = Arc::new(sdm::util::ThreadPool::new(cfg.resolved_pool_threads()));
    let mut hub = if toy_hub {
        EngineHub::from_infos(vec![
            sdm::model::gmm::testmodel::toy().info,
            sdm::model::gmm::testmodel::synthetic(16, 64).info,
        ])
    } else {
        EngineHub::load_with(&dir, backend, cache)?
    };
    if shard_min_rows > 0 {
        hub.attach_shard_pool(Arc::clone(&pool), shard_min_rows);
    }
    if let Some(plan) = &chaos {
        hub.apply_chaos(Arc::clone(plan));
        println!("sdm serving WITH FAULT INJECTION: {} (seed {})", plan.spec(), plan.seed());
    }
    let hub = Arc::new(hub);
    let server = Server::start_with_pool(hub, cfg, pool)?;
    println!(
        "sdm serving on {} (send {{\"op\":\"shutdown\"}} to stop)",
        server.local_addr
    );
    if let Some(a) = server.http_addr() {
        println!(
            "sdm http/sse gateway on http://{a}/ \
             (GET /stream, POST /cancel/{{request_id}}, POST /shutdown)"
        );
    }
    while !server.is_stopping() {
        std::thread::sleep(std::time::Duration::from_millis(200));
    }
    server.shutdown();
    println!("sdm server stopped");
    Ok(())
}

fn sample(args: &Args) -> Result<()> {
    let ctx = exp_context(args)?;
    let dataset = args.get("dataset", "cifar10g");
    let param = Param::from_name(&args.get("param", "edm"))?;
    let steps = args.get_usize("steps", 0)?;
    let solver_name = args.get("solver", "heun");
    let sched_name = args.get("schedule", "edm");
    let tau_k = args.get_f64("tau-k", 2e-4)?;
    let class = args.opt("class").map(|c| c.parse::<usize>()).transpose()?;
    let eta_min = args.opt("eta-min").map(|v| v.parse::<f64>()).transpose()?;
    let eta_max = args.opt("eta-max").map(|v| v.parse::<f64>()).transpose()?;
    let eta_p = args.get_f64("p", 1.0)?;
    let eta_q = args.get_f64("q", 0.25)?;
    let plan_str = args.opt("plan");
    let do_plan_search = args.has("plan-search");
    args.finish()?;

    // --plan-search: enumerate candidate plans for this (dataset, param,
    // budget) and report them ranked (lowest NFE within 5% of best FD)
    if do_plan_search {
        let steps = ctx.hub.resolve_steps(&dataset, steps)?;
        let ranked = experiments::plan_search(&ctx, &dataset, param, steps)?;
        println!("plan search — {dataset} ({}) @ {steps} steps", param.name());
        println!("{:<44} {:>10} {:>8}  {}", "plan", "FD", "NFE", "NFE/segment");
        for (plan, row) in &ranked {
            let seg = row
                .seg_nfe
                .iter()
                .map(|n| format!("{n:.1}"))
                .collect::<Vec<_>>()
                .join("/");
            println!("{:<44} {:>10.4} {:>8.1}  {}", plan.tag(), row.fd, row.nfe, seg);
        }
        println!("selected : {}", ranked[0].0.tag());
        return Ok(());
    }

    let solver = match solver_name.as_str() {
        "euler" => sdm::solvers::SolverSpec::Euler,
        "heun" => sdm::solvers::SolverSpec::Heun,
        "dpm2m" => sdm::solvers::SolverSpec::Dpm2m,
        "pid" => sdm::solvers::SolverSpec::Pid(sdm::solvers::PidParams::default()),
        "sdm" => sdm::solvers::SolverSpec::Adaptive {
            lambda: sdm::solvers::LambdaKind::Step,
            tau_k,
            clock: sdm::diffusion::CurvatureClock::Sigma,
        },
        other => anyhow::bail!("unknown solver {other}"),
    };
    // an explicit --plan (segmented, DESIGN.md §9 grammar) wins over --solver
    let plan = match &plan_str {
        Some(p) => sdm::sampler::SamplingPlan::parse(p)?,
        None => solver.into(),
    };
    let schedule = match sched_name.as_str() {
        "edm" => sdm::schedule::ScheduleSpec::Edm { rho: 7.0 },
        "cos" => sdm::schedule::ScheduleSpec::Cos { pilot_mult: 4, pilot_rows: 128 },
        "sdm" => {
            let mut spec = sdm::schedule::ScheduleSpec::sdm_defaults(&dataset, param);
            if let sdm::schedule::ScheduleSpec::Sdm { eta_min: em, eta_max: ex, p, q, .. } =
                &mut spec
            {
                if let Some(v) = eta_min {
                    *em = v;
                }
                if let Some(v) = eta_max {
                    *ex = v;
                }
                *p = eta_p;
                *q = eta_q;
            }
            spec
        }
        "linear" => sdm::schedule::ScheduleSpec::LinearSigma,
        "cosine" => sdm::schedule::ScheduleSpec::Cosine,
        "logsnr" => sdm::schedule::ScheduleSpec::LogSnr,
        other => anyhow::bail!("unknown schedule {other}"),
    };
    let cfg = sdm::sampler::SamplerConfig {
        dataset: dataset.clone(),
        param,
        plan,
        schedule,
        steps: ctx.hub.resolve_steps(&dataset, steps)?,
        class,
    };
    let timer = Timer::start();
    let row = experiments::evaluate(&ctx, &cfg)?;
    println!("config   : {}", row.label);
    println!("backend  : {:?}", ctx.hub.backend);
    println!("samples  : {}", ctx.samples);
    println!("FD       : {:.4}   (paper metric: FID)", row.fd);
    println!("slicedW2 : {:.4}", row.sliced);
    println!("NFE      : {:.1}", row.nfe);
    if cfg.plan.segments.len() > 1 {
        let seg = row
            .seg_nfe
            .iter()
            .map(|n| format!("{n:.1}"))
            .collect::<Vec<_>>()
            .join("/");
        println!("NFE/seg  : {seg}");
    }
    println!("wallclock: {:.1} ms", timer.elapsed_ms());
    Ok(())
}

fn schedule(args: &Args) -> Result<()> {
    let hub = load_hub(args)?;
    let dataset = args.get("dataset", "cifar10g");
    let param = Param::from_name(&args.get("param", "edm"))?;
    let steps = args.get_usize("steps", 0)?;
    let sched_name = args.get("schedule", "sdm");
    args.finish()?;
    let spec = match sched_name.as_str() {
        "edm" => sdm::schedule::ScheduleSpec::Edm { rho: 7.0 },
        "cos" => sdm::schedule::ScheduleSpec::Cos { pilot_mult: 4, pilot_rows: 128 },
        "sdm" => sdm::schedule::ScheduleSpec::sdm_defaults(&dataset, param),
        other => anyhow::bail!("unknown schedule {other}"),
    };
    let grid = hub.schedule(&dataset, param, &spec, steps)?;
    println!(
        "# {} / {} / {} ({} knots)",
        dataset,
        param.name(),
        spec.tag(),
        grid.sigmas.len()
    );
    for (i, s) in grid.sigmas.iter().enumerate() {
        println!("{i:>4} {s:.6}");
    }
    Ok(())
}

/// `sdm loadgen`: drive a coordinator with open-loop, closed-loop, or
/// SLO-searching load. `--in-process` spins up a native toy-workload
/// server inside this process (no artifacts needed — CI smoke and quick
/// local experiments); otherwise `--addr` names a running server.
fn loadgen(args: &Args) -> Result<()> {
    use sdm::coordinator::loadgen::{
        append_qos_record, closed_loop_with, find_max_rps, open_loop, sse_closed_loop,
        LoadOptions, RequestTemplate, SloSearch, TraceProfile,
    };
    use sdm::util::{BreakerConfig, RetryPolicy};

    let in_process = args.has("in-process");
    let addr_flag = args.get("addr", "127.0.0.1:7433");
    // SSE mode (DESIGN.md §13): stream samples from the HTTP gateway
    // instead of the socket front-end, with a seeded early-stop policy
    let sse = args.has("sse");
    let http_addr_flag = args.opt("http-addr");
    let cancel_rate = args.get_f64("cancel-rate", 0.0)?;
    let disconnect_rate = args.get_f64("disconnect-rate", 0.0)?;
    let stop_after = args.get_usize("stop-after", 2)?;
    // trace-profile shaping (open/closed loop): per-priority mix and
    // on/off burstiness
    let priority_mix = args.has("priority-mix");
    let burst_on_ms = args.get_f64("burst-on-ms", 0.0)?;
    let burst_off_ms = args.get_f64("burst-off-ms", 0.0)?;
    let closed = args.has("closed-loop");
    let workers = args.get_usize("workers", 4)?;
    let per_worker = args.get_u64("requests-per-worker", 32)?;
    let requests = args.get_u64("requests", 256)?;
    let think_ms = args.get_f64("think-ms", 0.0)?;
    let seed = args.get_u64("seed", 42)?;
    let slo_p99_ms = args.opt("slo-p99-ms").map(|v| v.parse::<f64>()).transpose()?;
    let max_workers = args.get_usize("max-workers", 32)?;
    let open_rps = args.get_f64("open-rps", 200.0)?;
    let out = args.get("out", "BENCH_qos.json");
    let label = args.get("label", "loadgen");
    // single-template profile flags (default profile: standard mix, or
    // the toy workload when in-process)
    let dataset = args.opt("dataset");
    let n = args.get_usize("n", 8)?;
    let param = args.get("param", "edm");
    let solver = args.get("solver", "euler");
    let plan = args.opt("plan");
    let schedule_name = args.get("schedule", "edm");
    let steps = args.get_usize("steps", 8)?;
    let priority = args.opt("priority");
    let deadline_ms = args.opt("deadline-ms").map(|v| v.parse::<f64>()).transpose()?;
    let kernel_precision = args.opt("kernel-precision");
    // client resilience (closed-loop only): --retry turns on
    // retry/backoff + per-route circuit breaking AND tags every request
    // with an idempotency request_id so ambiguous post-write failures
    // are safe to resend (DESIGN.md §12)
    let retry = args.has("retry");
    let retry_max = args.get_usize("retry-max", 4)?;
    let retry_base_ms = args.get_f64("retry-base-ms", 5.0)?;
    let retry_cap_ms = args.get_f64("retry-cap-ms", 250.0)?;
    let retry_budget_ms = args.get_f64("retry-budget-ms", 1000.0)?;
    let breaker_threshold = args.get_usize("breaker-threshold", 5)?;
    let breaker_cooldown_ms = args.get_f64("breaker-cooldown-ms", 250.0)?;
    // fault plan: injected server-side when --in-process; its conn_drop
    // clause also drives client-side connection drops under --retry
    let chaos_spec = args.opt("chaos");
    let chaos_seed = args.get_u64("chaos-seed", seed)?;
    args.finish()?;

    let think = std::time::Duration::from_secs_f64(think_ms.max(0.0) / 1e3);
    let template = |ds: String| RequestTemplate {
        dataset: ds,
        n,
        param: param.clone(),
        solver: solver.clone(),
        plan: plan.clone(),
        schedule: schedule_name.clone(),
        steps,
        priority: priority.clone(),
        deadline_ms,
        kernel_precision: kernel_precision.clone(),
        request_id: retry.then(|| "lg".to_string()),
    };
    let default_ds = if in_process { "toy".to_string() } else { "cifar10g".to_string() };
    let mut profile = if priority_mix {
        TraceProfile::priority_mix(dataset.as_deref().unwrap_or(&default_ds), n, steps)
    } else {
        match (&dataset, in_process) {
            (Some(ds), _) => TraceProfile::single(template(ds.clone())),
            (None, true) => TraceProfile::single(template("toy".to_string())),
            (None, false) => TraceProfile::standard(),
        }
    };
    if burst_on_ms > 0.0 && burst_off_ms > 0.0 {
        profile = profile.bursty(
            std::time::Duration::from_secs_f64(burst_on_ms / 1e3),
            std::time::Duration::from_secs_f64(burst_off_ms / 1e3),
        );
    }
    profile.chaos = chaos_spec.clone();

    // in-process server over the native toy workloads (synth16x64 is the
    // SIMD-eligible one, for --kernel-precision smoke runs)
    let server = if in_process {
        let mut hub = EngineHub::from_infos(vec![
            sdm::model::gmm::testmodel::toy().info,
            sdm::model::gmm::testmodel::synthetic(16, 64).info,
        ]);
        let mut cfg = ServerConfig::default();
        if sse {
            // SSE mode drives the gateway, so the in-process server
            // needs one (ephemeral port)
            cfg.http_addr = Some("127.0.0.1:0".to_string());
        }
        if let Some(spec) = &chaos_spec {
            let chaos = Arc::new(sdm::chaos::FaultPlan::parse(spec, chaos_seed)?);
            hub.apply_chaos(Arc::clone(&chaos));
            cfg.chaos = Some(chaos);
        }
        Some(Server::start(Arc::new(hub), cfg)?)
    } else {
        None
    };
    let addr = server
        .as_ref()
        .map(|s| s.local_addr.to_string())
        .unwrap_or(addr_flag);

    let result = (|| -> Result<()> {
        if sse {
            let http_addr = match (&server, &http_addr_flag) {
                (Some(s), _) => s
                    .http_addr()
                    .map(|a| a.to_string())
                    .ok_or_else(|| anyhow::anyhow!("in-process server started no gateway"))?,
                (None, Some(a)) => a.clone(),
                (None, None) => {
                    anyhow::bail!("--sse needs --http-addr (or --in-process)")
                }
            };
            let mut tpl = template(dataset.clone().unwrap_or(default_ds));
            if cancel_rate > 0.0 && tpl.request_id.is_none() {
                // POST /cancel/{id} needs an id to target
                tpl.request_id = Some("lg".to_string());
            }
            let report = sse_closed_loop(
                &http_addr, &tpl, workers, per_worker, cancel_rate, disconnect_rate,
                stop_after, seed,
            )?;
            println!(
                "sse closed-loop: {} workers x {} streams -> {} served, {} cancelled \
                 ({:.1} NFE refunded), {} disconnected, {} errors, {} progress events \
                 in {:.1}s",
                workers, per_worker, report.served, report.cancelled, report.nfe_refunded,
                report.disconnected, report.errors, report.progress_events, report.wall_s
            );
            println!("  latency (done streams): {}", report.latency.summary("us"));
            return Ok(());
        }
        if let Some(slo) = slo_p99_ms {
            let cfg = SloSearch {
                slo_p99_ms: slo,
                max_workers,
                per_worker,
                think,
                seed,
            };
            let report = find_max_rps(&addr, &profile, &cfg)?;
            for p in &report.probes {
                println!(
                    "  probe workers={:<3} -> {:.1} req/s, p99 {:.0} us ({})",
                    p.workers,
                    p.rps,
                    p.p99_us,
                    if p.met { "meets SLO" } else { "misses SLO" }
                );
            }
            println!(
                "slo-search: p99 < {slo} ms holds up to {} workers -> max {:.1} req/s \
                 (p50 {:.0} us, p99 {:.0} us, {} sheds, {} expiries)",
                report.workers, report.max_rps, report.p50_us, report.p99_us,
                report.sheds, report.expiries
            );
            let out_path = std::path::PathBuf::from(&out);
            append_qos_record(&out_path, &label, slo, &report)?;
            println!("loadgen: appended run {label:?} to {}", out_path.display());
        } else if closed {
            let opts = LoadOptions {
                retry: retry.then_some(RetryPolicy {
                    max_attempts: retry_max,
                    base_ms: retry_base_ms,
                    cap_ms: retry_cap_ms,
                    budget_ms: retry_budget_ms,
                }),
                breaker: retry.then_some(BreakerConfig {
                    threshold: breaker_threshold,
                    cooldown: std::time::Duration::from_secs_f64(
                        breaker_cooldown_ms.max(0.0) / 1e3,
                    ),
                }),
                chaos: None,
            };
            let report =
                closed_loop_with(&addr, &profile, workers, per_worker, think, seed, &opts)?;
            println!(
                "closed-loop: {} workers x {} reqs (think {:.1} ms) -> {:.1} req/s goodput, \
                 {} errors, {} sheds, {} expiries, {} cancelled  [trace {:016x}]",
                workers, per_worker, think_ms, report.goodput_rps(),
                report.errors, report.sheds, report.expiries, report.cancelled,
                report.trace_hash
            );
            println!("  latency: {}", report.latency.summary("us"));
            if retry {
                println!(
                    "  resilience: {} retries, {} reconnects, {} breaker opens, \
                     {} fast-fails, {} double-submits avoided",
                    report.retries, report.reconnects, report.breaker_opens,
                    report.breaker_fast_fails, report.double_submit_avoided
                );
            }
        } else {
            let report = open_loop(&addr, &profile, open_rps, requests, workers, seed)?;
            println!(
                "open-loop: offered {open_rps} req/s, sent {} ({} errors, {} sheds, \
                 {} expiries) in {:.1}s -> {:.1} req/s achieved",
                report.sent, report.errors, report.sheds, report.expiries,
                report.wall_s, report.throughput_rps()
            );
            println!("  latency: {}", report.latency.summary("us"));
        }
        Ok(())
    })();
    if let Some(s) = server {
        s.shutdown();
    }
    result
}

fn bench_client(args: &Args) -> Result<()> {
    let addr = args.get("addr", "127.0.0.1:7433");
    let requests = args.get_usize("requests", 256)?;
    let concurrency = args.get_usize("concurrency", 8)?;
    let n = args.get_usize("n", 16)?;
    let dataset = args.get("dataset", "cifar10g");
    let solver = args.get("solver", "sdm");
    let steps = args.get_usize("steps", 18)?;
    let open_rps = args.opt("open-loop-rps").map(|v| v.parse::<f64>()).transpose()?;
    args.finish()?;

    // open-loop Poisson mode: honest queueing measurement under offered load
    if let Some(rps) = open_rps {
        let profile = sdm::coordinator::loadgen::TraceProfile::standard();
        let report = sdm::coordinator::loadgen::open_loop(
            &addr, &profile, rps, requests as u64, concurrency, 42)?;
        println!(
            "open-loop: offered {rps} req/s, sent {} ({} errors) in {:.1}s -> {:.1} req/s achieved",
            report.sent, report.errors, report.wall_s, report.throughput_rps()
        );
        println!("  latency: {}", report.latency.summary("us"));
        return Ok(());
    }

    let timer = Timer::start();
    let per_thread = requests / concurrency;
    let mut handles = Vec::new();
    for tid in 0..concurrency {
        let addr = addr.clone();
        let dataset = dataset.clone();
        let solver = solver.clone();
        handles.push(std::thread::spawn(move || -> Result<Histogram> {
            let mut client = Client::connect(&addr)?;
            let mut hist = Histogram::new();
            for i in 0..per_thread {
                let t = Timer::start();
                let resp = client.sample(
                    &dataset,
                    n,
                    "edm",
                    &solver,
                    "edm",
                    steps,
                    (tid * 1000 + i) as u64,
                )?;
                anyhow::ensure!(
                    resp.get("ok")? == &sdm::util::Json::Bool(true),
                    "request failed: {resp:?}"
                );
                hist.record(t.elapsed_us());
            }
            Ok(hist)
        }));
    }
    let mut total = Histogram::new();
    for h in handles {
        total.merge(&h.join().unwrap()?);
    }
    let wall_s = timer.elapsed_us() / 1e6;
    let done = total.count();
    println!("bench-client: {done} requests x {n} samples, concurrency {concurrency}");
    println!("  latency: {}", total.summary("us"));
    println!(
        "  throughput: {:.1} req/s, {:.1} samples/s",
        done as f64 / wall_s,
        (done as usize * n) as f64 / wall_s
    );
    Ok(())
}

fn print_help() {
    println!(
        "sdm — Sampling Design space of diffusion Models (adaptive solvers +\n\
         Wasserstein-bounded timesteps), three-layer rust+JAX+Pallas serving repro.\n\n\
         subcommands:\n\
         \x20 serve         start the TCP coordinator (--addr, --backend,\n\
         \x20               --pool-threads N, --max-inflight N, --shard-min-rows N\n\
         \x20               [0 disables row-sharded native kernel evals];\n\
         \x20               --toy serves the built-in toy+synth16x64 hub, no\n\
         \x20               artifacts needed)\n\
         \x20               http/sse gateway [DESIGN.md S13]: --http-addr H:P\n\
         \x20               adds a streaming HTTP front-end — GET /stream\n\
         \x20               emits one progress event per solver step plus a\n\
         \x20               done|error|cancelled terminal; POST\n\
         \x20               /cancel/REQUEST_ID (or a dropped client socket, or\n\
         \x20               a superseding request_id) aborts mid-sample at the\n\
         \x20               next step boundary and refunds the remaining NFE\n\
         \x20               budget (stats: cancelled, nfe_refunded); GET /\n\
         \x20               serves a browser demo, GET /healthz + /stats probe,\n\
         \x20               POST /shutdown stops the server; omitted => no\n\
         \x20               listener, socket path byte-identical\n\
         \x20               schedule cache: --cache-capacity N (0=unbounded),\n\
         \x20               --cache-ttl-s SECS (0=never expire),\n\
         \x20               --no-cache-persist, --no-warm-start (serve defaults\n\
         \x20               both ON; experiment subcommands default OFF for\n\
         \x20               reproducibility — opt in with --cache-persist,\n\
         \x20               --warm-start)\n\
         \x20               QoS: --inbox-depth N (max outstanding requests per\n\
         \x20               route; 0=unbounded, overflow gets queue_full),\n\
         \x20               --qos-weight ds=w,... (DRR fairness weights,\n\
         \x20               default 1), --qos-slots N (global concurrent\n\
         \x20               flushes; 0=pool threads), --qos-quantum ROWS\n\
         \x20               (DRR credit/round; 0=max_batch), --qos-retry-ms MS\n\
         \x20               (back-off hint in queue_full replies); requests may\n\
         \x20               carry \"priority\":interactive|batch|background and\n\
         \x20               \"deadline_ms\" (late requests shed, never served\n\
         \x20               stale)\n\
         \x20               chaos: --chaos \"eval_err@1/200,eval_delay@p50=5ms,\n\
         \x20               conn_drop@1/50,cache_corrupt@1/20,batcher_panic@1/500\"\n\
         \x20               --chaos-seed S  seeded deterministic fault injection\n\
         \x20               [DESIGN.md S12]; off by default (all hooks are\n\
         \x20               zero-cost no-ops); probes: {{\"op\":\"health\"}}\n\
         \x20               liveness, {{\"op\":\"ready\"}} readiness (false while\n\
         \x20               draining or any batcher thread is down)\n\
         \x20 sample        one evaluation run (--dataset --solver --schedule --steps ...;\n\
         \x20               --plan \"euler@max..2,dpm2m@2..0\" runs a segmented\n\
         \x20               SamplingPlan [DESIGN.md S9] and wins over --solver;\n\
         \x20               --plan-search ranks candidate plans by NFE within\n\
         \x20               5% of the best FD for this dataset/param/budget;\n\
         \x20               --kernel-precision exact|fast-f64|fast-f32 selects\n\
         \x20               the denoiser tier [DESIGN.md S10]: exact is\n\
         \x20               bit-identical, fast tiers take the SIMD/tiled\n\
         \x20               kernel on eligible models; --toy runs on the\n\
         \x20               built-in toy+synth16x64 hub, no artifacts needed)\n\
         \x20 schedule      print a built sigma grid (--dataset --schedule --steps)\n\
         \x20 table1        Table 1  (unconditional FD/NFE grid)\n\
         \x20 table4        Table 4  (conditional)\n\
         \x20 table5        Table 5  (lambda ablation)\n\
         \x20 grid-tau|fig4 Table 2 / Figure 4 (tau_k sweep)\n\
         \x20 grid-eta      Table 3  (eta/p/q grid)\n\
         \x20 fig2          curvature vs sigma\n\
         \x20 fig3          eta_t budget over steps\n\
         \x20 pareto        quality-vs-NFE frontier: static solvers vs segmented\n\
         \x20               plans vs PID, with per-segment NFE attribution\n\
         \x20               (--smoke: artifact-free toy run for CI;\n\
         \x20               --smoke --kernel-precision fast-f32 also drives\n\
         \x20               the SIMD kernel on an eligible synthetic)\n\
         \x20 qualitative   sample dumps (Figs. 5-9 analogue)\n\
         \x20 bench-client  drive a running server (--addr --requests --concurrency\n\
         \x20               [--open-loop-rps R  Poisson offered-load mode])\n\
         \x20 loadgen       workload generator (--addr A | --in-process):\n\
         \x20               --closed-loop --workers N --requests-per-worker R\n\
         \x20               --think-ms T [--slo-p99-ms MS  binary-search the\n\
         \x20               highest load meeting the SLO; appends\n\
         \x20               {{max_rps,p50,p99,sheds,expiries}} to --out\n\
         \x20               BENCH_qos.json, --max-workers W, --label L]; default\n\
         \x20               mode is open-loop at --open-rps R for --requests N;\n\
         \x20               profile: --dataset D --n N --param P --solver S\n\
         \x20               --plan \"euler@max..1,heun@1..0\" (wins over --solver)\n\
         \x20               --schedule C --steps K --priority CLS --deadline-ms MS\n\
         \x20               --kernel-precision exact|fast-f64|fast-f32\n\
         \x20               resilience (closed-loop): --retry [--retry-max N\n\
         \x20               --retry-base-ms B --retry-cap-ms C --retry-budget-ms T\n\
         \x20               --breaker-threshold K --breaker-cooldown-ms MS] —\n\
         \x20               decorrelated-jitter backoff honoring the server's\n\
         \x20               retry_after_ms hint, per-route circuit breaker, and\n\
         \x20               idempotency request_ids so retries never double-\n\
         \x20               submit; --chaos PLAN --chaos-seed S injects faults\n\
         \x20               into the --in-process server (conn_drop also drops\n\
         \x20               client connections under --retry)\n\
         \x20               profiles: --priority-mix (interactive/batch/\n\
         \x20               background 30/50/20 on one dataset),\n\
         \x20               --burst-on-ms A --burst-off-ms B (open-loop on/off\n\
         \x20               burst envelope)\n\
         \x20               sse mode [DESIGN.md S13]: --sse streams samples\n\
         \x20               from the http gateway (--http-addr H:P, or the\n\
         \x20               --in-process server's own gateway); per-stream\n\
         \x20               early-stop policy: --cancel-rate F (POST /cancel\n\
         \x20               after --stop-after K progress events),\n\
         \x20               --disconnect-rate F (drop the socket instead);\n\
         \x20               reports served/cancelled/NFE-refunded/disconnected\n\
         \x20 bench-sampler denoiser-kernel + run_sampler perf harness; appends a\n\
         \x20               labeled run to BENCH_sampler.json (--smoke --label L --out F)\n\
         \x20 analyze       in-repo static analysis over rust/src (lock-order,\n\
         \x20               panic-policy zones, no-alloc hot paths, wire-schema\n\
         \x20               drift) [DESIGN.md S11]: --deny exit non-zero on\n\
         \x20               findings, --baseline .lint-baseline, --json, --root DIR\n\
         \x20 ablate-clock  curvature-clock ablation; ablate-refgrid: Alg.1 warm-start\n\n\
         common flags: --artifacts DIR --backend pjrt|native --samples N --seed S\n\
         \x20             --kernel-precision exact|fast-f64|fast-f32 --toy"
    );
}
