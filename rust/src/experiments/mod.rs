//! Experiment harness: regenerates every table and figure of the paper
//! (DESIGN.md §4 maps experiment ids to modules; EXPERIMENTS.md records
//! the measured outputs).
//!
//! The headline metric is the Fréchet distance FD (the FID formula on
//! exact reference moments — DESIGN.md §2); sliced-W₂ is reported as a
//! secondary column. Paper-vs-measured comparisons are about *shape*:
//! orderings, relative gaps, crossovers.

pub mod ablations;
pub mod figures;
pub mod grids;
pub mod pareto;
pub mod qualitative;
pub mod table1;
pub mod table4;
pub mod table5;

use std::sync::Arc;

use crate::coordinator::EngineHub;
use crate::diffusion::Param;
use crate::metrics::{frechet_to_reference, sample_mean_cov, sliced_w2};
use crate::sampler::{engine, RunConfig, SamplerConfig};
use crate::Result;

/// Shared evaluation settings.
#[derive(Clone)]
pub struct ExpContext {
    pub hub: Arc<EngineHub>,
    /// samples generated per (config, class) evaluation.
    pub samples: usize,
    /// integration batch rows.
    pub rows: usize,
    pub seed: u64,
    /// worker threads for config-parallel sweeps.
    pub threads: usize,
    /// shared worker pool: when set, [`evaluate`] row-shards its batches
    /// via [`engine::generate_pooled`] (identical output, concurrent
    /// execution), and [`evaluate_all`] reuses it for config parallelism.
    pub pool: Option<Arc<crate::util::ThreadPool>>,
}

impl ExpContext {
    pub fn new(hub: Arc<EngineHub>) -> ExpContext {
        ExpContext { hub, samples: 8192, rows: 256, seed: 2026, threads: 8, pool: None }
    }

    /// Attach a freshly built pool sized to `self.threads`.
    pub fn with_pool(mut self) -> ExpContext {
        self.pool = Some(Arc::new(crate::util::ThreadPool::new(self.threads.max(1))));
        self
    }
}

/// One evaluated table cell.
#[derive(Clone, Debug)]
pub struct RowResult {
    pub label: String,
    pub fd: f64,
    pub sliced: f64,
    pub nfe: f64,
}

/// Evaluate a sampler configuration: generate samples, compare against the
/// exact reference moments (class-restricted when conditional).
pub fn evaluate(ctx: &ExpContext, cfg: &SamplerConfig) -> Result<RowResult> {
    let info = ctx.hub.info(&cfg.dataset)?.clone();
    let model = ctx.hub.model(&cfg.dataset)?;
    let oracle = ctx.hub.oracle(&cfg.dataset)?;
    let grid = ctx.hub.schedule(&cfg.dataset, cfg.param, &cfg.schedule, cfg.steps)?;

    let run_cfg = RunConfig {
        rows: ctx.rows,
        seed: ctx.seed ^ fxhash(&cfg.label()),
        class: cfg.class,
        trace: false,
    };
    let (samples, nfe, _) = match &ctx.pool {
        Some(pool) => engine::generate_pooled(
            &model,
            cfg.param,
            &grid,
            &cfg.solver,
            &info,
            &run_cfg,
            ctx.samples,
            pool,
        )?,
        None => engine::generate(
            model.as_ref(),
            cfg.param,
            &grid,
            &cfg.solver,
            &info,
            &run_cfg,
            ctx.samples,
        )?,
    };

    let stats = sample_mean_cov(&samples, info.dim);
    let (ref_mean, ref_cov) = match cfg.class {
        Some(c) => oracle.class_moments(c),
        None => (info.exact_mean.clone(), info.exact_cov.clone()),
    };
    let fd = frechet_to_reference(&stats, &ref_mean, &ref_cov)?;

    // sliced-W2 against a fresh ground-truth draw
    let mut rng = crate::util::Rng::new(run_cfg.seed ^ 0xABCD);
    let truth64 = oracle.sample_data(&mut rng, ctx.samples.min(4096), cfg.class);
    let truth: Vec<f32> = truth64.iter().map(|&v| v as f32).collect();
    let gen_sub = &samples[..ctx.samples.min(4096) * info.dim];
    let sl = sliced_w2(gen_sub, &truth, info.dim, 48, run_cfg.seed ^ 0x51ED);

    Ok(RowResult { label: cfg.label(), fd, sliced: sl, nfe })
}

/// Evaluate a list of configs, parallel over the shared worker pool.
///
/// Config-level jobs and each config's row shards share one pool (the
/// help-first scheduling of [`engine::generate_pooled`] makes the nesting
/// deadlock-free), so a sweep with fewer configs than workers still
/// saturates the machine.
pub fn evaluate_all(ctx: &ExpContext, cfgs: Vec<SamplerConfig>) -> Vec<Result<RowResult>> {
    if cfgs.is_empty() {
        return Vec::new();
    }
    // PJRT executes on a single executor thread anyway; parallelism only
    // helps the native backend, but is harmless either way.
    let pool = match &ctx.pool {
        Some(p) => p.clone(),
        None => Arc::new(crate::util::ThreadPool::new(ctx.threads.max(1))),
    };
    let ctx2 = ExpContext { pool: Some(pool.clone()), ..ctx.clone() };
    let cfgs = Arc::new(cfgs);
    let cfgs2 = cfgs.clone();
    pool.map_indices(cfgs.len(), move |i| evaluate(&ctx2, &cfgs2[i]))
}

/// Deterministic label hash (seed derivation).
pub fn fxhash(s: &str) -> u64 {
    s.bytes()
        .fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3))
}

/// Paper parameterization pairs used by the unconditional tables.
pub fn table_params() -> Vec<Param> {
    vec![Param::vp(), Param::Ve]
}

/// Fixed-width table cell for FD / NFE printing.
pub fn fmt_cell(fd: f64, nfe: f64) -> String {
    format!("{fd:>8.4} @{nfe:>5.1}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gmm::testmodel::toy;
    use crate::schedule::ScheduleSpec;
    use crate::solvers::SolverSpec;

    fn ctx() -> ExpContext {
        let hub = Arc::new(EngineHub::from_infos(vec![toy().info]));
        ExpContext { hub, samples: 2048, rows: 256, seed: 7, threads: 4, pool: None }
    }

    #[test]
    fn evaluate_produces_sane_metrics() {
        let ctx = ctx();
        let cfg = SamplerConfig::edm_baseline("toy", Param::Edm, 16);
        let row = evaluate(&ctx, &cfg).unwrap();
        assert!(row.fd.is_finite() && row.fd >= 0.0 && row.fd < 1.0, "{row:?}");
        assert!(row.sliced.is_finite() && row.sliced < 1.0, "{row:?}");
        assert_eq!(row.nfe, 31.0); // 2*16-1
    }

    #[test]
    fn conditional_evaluation_uses_class_moments() {
        let ctx = ctx();
        let mut cfg = SamplerConfig::edm_baseline("toy", Param::Edm, 16);
        cfg.class = Some(1);
        let row = evaluate(&ctx, &cfg).unwrap();
        assert!(row.fd < 1.0, "{row:?}");
    }

    #[test]
    fn evaluate_all_parallel_matches_serial() {
        let ctx = ctx();
        let cfgs = vec![
            SamplerConfig::edm_baseline("toy", Param::Edm, 8),
            SamplerConfig {
                solver: SolverSpec::Euler,
                ..SamplerConfig::edm_baseline("toy", Param::Edm, 8)
            },
            SamplerConfig {
                schedule: ScheduleSpec::LogSnr,
                ..SamplerConfig::edm_baseline("toy", Param::Ve, 8)
            },
        ];
        let rows = evaluate_all(&ctx, cfgs.clone());
        assert_eq!(rows.len(), 3);
        for (r, c) in rows.iter().zip(&cfgs) {
            let serial = evaluate(&ctx, c).unwrap();
            let par = r.as_ref().unwrap();
            assert_eq!(par.fd, serial.fd, "parallel/serial mismatch for {}", c.label());
        }
    }
}
