//! Server-Sent-Events framing and plain HTTP responses for the gateway.
//!
//! SSE is the simplest standard streaming shape a browser `EventSource`
//! speaks: a `text/event-stream` body of `event:`/`data:` line pairs,
//! each record terminated by a blank line. The gateway streams one
//! `progress` record per solver step and terminates with exactly one of
//! `done` / `error` / `cancelled` (DESIGN.md §13). Payload JSON is built
//! by the protocol module ([`crate::coordinator::protocol`]) so wire keys
//! have a single origin.

use std::io::Write;

/// Response head opening an SSE stream. `Connection: close` — the
/// gateway is one-request-per-connection by design.
pub fn stream_head() -> &'static str {
    "HTTP/1.1 200 OK\r\ncontent-type: text/event-stream\r\ncache-control: no-cache\r\nconnection: close\r\n\r\n"
}

/// Write one SSE record. `data` must be a single line (the gateway's
/// payloads are JSON lines, which never embed raw newlines).
pub fn write_event(w: &mut dyn Write, event: &str, data: &str) -> std::io::Result<()> {
    // one write call per record so a disconnect tears between records,
    // not inside one
    let frame = format!("event: {event}\ndata: {data}\n\n");
    w.write_all(frame.as_bytes())?;
    w.flush()
}

/// A complete non-streaming HTTP response.
pub fn response(status: u16, reason: &str, content_type: &str, body: &str) -> String {
    format!(
        "HTTP/1.1 {status} {reason}\r\ncontent-type: {content_type}\r\ncontent-length: {}\r\nconnection: close\r\n\r\n{body}",
        body.len()
    )
}

/// A JSON-bodied response, the gateway's default shape.
pub fn json_response(status: u16, reason: &str, body: &str) -> String {
    response(status, reason, "application/json", body)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_frames_terminate_with_blank_line() {
        let mut buf = Vec::new();
        write_event(&mut buf, "progress", r#"{"step":1}"#).unwrap();
        assert_eq!(
            String::from_utf8(buf).unwrap(),
            "event: progress\ndata: {\"step\":1}\n\n"
        );
    }

    #[test]
    fn responses_carry_length_and_close() {
        let r = json_response(200, "OK", r#"{"ok":true}"#);
        assert!(r.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(r.contains("content-length: 11\r\n"));
        assert!(r.contains("connection: close\r\n"));
        assert!(r.ends_with(r#"{"ok":true}"#));
    }

    #[test]
    fn stream_head_declares_event_stream() {
        assert!(stream_head().contains("text/event-stream"));
        assert!(stream_head().ends_with("\r\n\r\n"));
    }
}
