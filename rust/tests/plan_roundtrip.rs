//! SamplingPlan round-trip and cache-identity guards (DESIGN.md §9).
//!
//! A plan string travels CLI → wire protocol → batch group → schedule
//! cache key. These tests pin each hop: parse/tag round-trips, the
//! protocol's `"plan"` field resolves to the same plan, and — the
//! regression the refactor must never lose — segmented plans get their
//! own schedule-cache entries while single-segment plans keep the exact
//! pre-plan keys (no aliasing in either direction).

use std::sync::Arc;

use sdm::coordinator::protocol::{PlanRequest, Request};
use sdm::coordinator::EngineHub;
use sdm::diffusion::Param;
use sdm::model::gmm::testmodel::toy;
use sdm::sampler::SamplingPlan;
use sdm::schedule::cache::CacheKey;
use sdm::schedule::ScheduleSpec;
use sdm::solvers::SolverSpec;

#[test]
fn plan_strings_round_trip_through_parse_and_tag() {
    // segmented plan tags are in the plan grammar: parse(tag(p)) == p
    for s in [
        "euler@max..2,dpm2m@2..0",
        "euler@max..2,heun@2..0.5,sdm@0.5..0",
        "heun@max..0.5,sdm(tau=0.0002)@0.5..0",
        "euler@max..1.25,pid(rtol=0.01)@1.25..0",
    ] {
        let p = SamplingPlan::parse(s).unwrap();
        let p2 = SamplingPlan::parse(&p.tag()).unwrap();
        assert_eq!(p.tag(), p2.tag(), "tag must be a fixed point for {s:?}");
        assert_eq!(p.cache_tag(), p.tag(), "segmented plans carry their full tag");
    }
    // bare solver names parse to single-segment plans whose tag is the
    // legacy solver tag (labels/group keys unchanged)
    for s in ["euler", "heun", "dpm2m", "sdm", "pid"] {
        let p = SamplingPlan::parse(s).unwrap();
        assert!(p.is_single(), "{s:?} should be single-segment");
        assert_eq!(p.cache_tag(), "", "single-segment plans add no cache discriminator");
        let p2 = SamplingPlan::parse(&p.tag()).unwrap();
        assert_eq!(p.tag(), p2.tag());
    }
    // whole-range explicit form collapses to the bare solver
    let p = SamplingPlan::parse("euler@max..0").unwrap();
    assert!(matches!(p.solo(), Some(SolverSpec::Euler)));
    assert_eq!(p.tag(), "euler");
}

#[test]
fn protocol_plan_field_resolves_to_the_parsed_plan() {
    let line = r#"{"op":"sample","dataset":"toy","n":2,"plan":"euler@max..2,dpm2m@2..0","steps":8}"#;
    let Request::Sample(req) = Request::parse(line).unwrap() else {
        panic!("expected a sample request");
    };
    let PlanRequest::Explicit(plan) = &req.plan else {
        panic!("explicit plan string must parse to Explicit");
    };
    assert_eq!(plan.tag(), "euler@max..2,dpm2m@2..0");
    assert_eq!(plan.tag(), SamplingPlan::parse("euler@max..2,dpm2m@2..0").unwrap().tag());

    // "auto" defers to the hub's instance bucket
    let line = r#"{"op":"sample","dataset":"toy","n":2,"plan":"auto","steps":8}"#;
    let Request::Sample(req) = Request::parse(line).unwrap() else {
        panic!("expected a sample request");
    };
    assert!(matches!(req.plan, PlanRequest::Auto));

    // legacy requests (no "plan") keep resolving through "solver"
    let line = r#"{"op":"sample","dataset":"toy","n":2,"solver":"heun","steps":8}"#;
    let Request::Sample(req) = Request::parse(line).unwrap() else {
        panic!("expected a sample request");
    };
    let PlanRequest::Explicit(plan) = &req.plan else {
        panic!("legacy solver must resolve to an explicit single-segment plan");
    };
    assert!(matches!(plan.solo(), Some(SolverSpec::Heun)));
}

#[test]
fn cache_keys_never_alias_across_plans() {
    let base = CacheKey {
        dataset: "toy".into(),
        param: "edm".into(),
        tag: "edm(7)".into(),
        steps: 8,
        model_fp: 0xABCD,
        plan: String::new(),
    };
    let seg1 = CacheKey { plan: "euler@max..2,dpm2m@2..0".into(), ..base.clone() };
    let seg2 = CacheKey { plan: "euler@max..2,heun@2..0".into(), ..base.clone() };

    // single-segment keys are byte-identical to the pre-plan encoding
    assert_eq!(base.encode(), "toy|edm|edm(7)|8|abcd");
    // segmented keys are distinct from the plain key and from each other
    let enc: Vec<String> = vec![base.encode(), seg1.encode(), seg2.encode()];
    for i in 0..enc.len() {
        for j in 0..enc.len() {
            if i != j {
                assert_ne!(enc[i], enc[j], "cache keys alias: {:?}", enc[i]);
            }
        }
    }
}

#[test]
fn hub_builds_separate_grids_per_plan_and_shares_the_single_segment_one() {
    let hub = Arc::new(EngineHub::from_infos(vec![toy().info]));
    let spec = ScheduleSpec::Edm { rho: 7.0 };
    assert_eq!(hub.cached_schedules(), 0);

    // two single-segment plans (and the legacy entry point) share a grid
    let g_legacy = hub.schedule("toy", Param::Edm, &spec, 8).unwrap();
    let g_euler = hub
        .schedule_for_plan("toy", Param::Edm, &spec, 8, &SamplingPlan::parse("euler").unwrap().cache_tag())
        .unwrap();
    let g_heun = hub
        .schedule_for_plan("toy", Param::Edm, &spec, 8, &SamplingPlan::parse("heun").unwrap().cache_tag())
        .unwrap();
    assert_eq!(hub.cached_schedules(), 1, "single-segment plans must share one cached grid");
    assert_eq!(g_legacy.sigmas, g_euler.sigmas);
    assert_eq!(g_legacy.sigmas, g_heun.sigmas);

    // a segmented plan adds its own entry; a different segmented plan adds
    // another (no aliasing), and repeating either is a cache hit
    let info = hub.info("toy").unwrap();
    let b = info.sigma_max * 0.025;
    let p1 = SamplingPlan::parse(&format!("euler@max..{b},dpm2m@{b}..0")).unwrap();
    let p2 = SamplingPlan::parse(&format!("euler@max..{b},heun@{b}..0")).unwrap();
    hub.schedule_for_plan("toy", Param::Edm, &spec, 8, &p1.cache_tag()).unwrap();
    assert_eq!(hub.cached_schedules(), 2);
    hub.schedule_for_plan("toy", Param::Edm, &spec, 8, &p2.cache_tag()).unwrap();
    assert_eq!(hub.cached_schedules(), 3);
    hub.schedule_for_plan("toy", Param::Edm, &spec, 8, &p1.cache_tag()).unwrap();
    assert_eq!(hub.cached_schedules(), 3, "repeat plan lookups must hit, not rebuild");
}
