// Seeded violation: a bare `unwrap` on a coordinator reply path. The
// test-module copy below must stay exempt.
// (Never compiled: fixture input for `sdm analyze` tests only.)

pub fn reply_line(v: Option<u32>) -> String {
    let n = v.unwrap();
    format!("ok {n}")
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_exempt() {
        assert_eq!(super::reply_line(Some(1)).len().max(0), 4);
        let _ = Some(2u32).unwrap();
    }
}
