//! SDM adaptive solver pieces (paper §3.1.2).
//!
//! The scheduling function Λ(t) ∈ [0,1] mixes the Euler and Heun outputs
//! (eq. 9): x = Λ·x^E + (1−Λ)·x^H. Step-Λ specializes to a *gate*: when
//! the cached curvature proxy κ̂_rel(i) (eq. 8) is below τ_k the Heun
//! correction — and its extra NFE — is skipped entirely, which is why the
//! step scheduler achieves NFE < 2 per interval (paper Table 5).

/// Λ(t) families considered by the paper (step / linear / cosine).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LambdaKind {
    /// Λ = 1 while κ̂ < τ_k (pure Euler, no second eval), else 0 (Heun).
    Step,
    /// Λ decreases linearly in step progress: 1 at i=0, 0 at i=N−1.
    Linear,
    /// Λ = cos²(π/2 · u): Nichol–Dhariwal-shaped decay.
    Cosine,
}

impl LambdaKind {
    pub fn tag(&self) -> &'static str {
        match self {
            LambdaKind::Step => "step",
            LambdaKind::Linear => "linear",
            LambdaKind::Cosine => "cosine",
        }
    }

    pub fn from_name(name: &str) -> anyhow::Result<LambdaKind> {
        match name {
            "step" => Ok(LambdaKind::Step),
            "linear" => Ok(LambdaKind::Linear),
            "cosine" => Ok(LambdaKind::Cosine),
            other => anyhow::bail!("unknown lambda schedule {other:?}"),
        }
    }

    /// Blend weight for interval i of n (continuous kinds only).
    pub fn lambda(&self, i: usize, n: usize) -> f64 {
        let u = if n <= 1 { 1.0 } else { i as f64 / (n - 1) as f64 };
        match self {
            LambdaKind::Step => unreachable!("step lambda is curvature-gated"),
            LambdaKind::Linear => 1.0 - u,
            LambdaKind::Cosine => {
                let c = (std::f64::consts::FRAC_PI_2 * u).cos();
                c * c
            }
        }
    }
}

/// Convex combination x = Λ·x^E + (1−Λ)·x^H written into `out` (eq. 9).
pub fn blend(x_euler: &[f32], x_heun: &[f32], lambda: f64, out: &mut [f32]) {
    debug_assert_eq!(x_euler.len(), x_heun.len());
    debug_assert_eq!(x_euler.len(), out.len());
    let l = lambda as f32;
    let one_l = 1.0 - l;
    for i in 0..out.len() {
        out[i] = l * x_euler[i] + one_l * x_heun[i];
    }
}

/// The step-Λ gate: use Heun iff the cached curvature estimate crossed the
/// threshold. The first interval has no cached velocity (κ̂ undefined) and
/// runs Euler — consistent with the near-linear high-noise regime.
pub fn step_gate(kappa_hat: Option<f64>, tau_k: f64) -> bool {
    match kappa_hat {
        Some(k) => k >= tau_k,
        None => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lambda_boundaries() {
        for kind in [LambdaKind::Linear, LambdaKind::Cosine] {
            assert!((kind.lambda(0, 10) - 1.0).abs() < 1e-12);
            assert!(kind.lambda(9, 10).abs() < 1e-12);
            // monotone decreasing
            for i in 1..10 {
                assert!(kind.lambda(i, 10) <= kind.lambda(i - 1, 10) + 1e-12);
            }
        }
    }

    #[test]
    fn blend_endpoints() {
        let e = vec![1.0f32, 2.0];
        let h = vec![3.0f32, 6.0];
        let mut out = vec![0.0f32; 2];
        blend(&e, &h, 1.0, &mut out);
        assert_eq!(out, e);
        blend(&e, &h, 0.0, &mut out);
        assert_eq!(out, h);
        blend(&e, &h, 0.5, &mut out);
        assert_eq!(out, vec![2.0, 4.0]);
    }

    #[test]
    fn gate_logic() {
        assert!(!step_gate(None, 1e-4));
        assert!(!step_gate(Some(5e-5), 1e-4));
        assert!(step_gate(Some(2e-4), 1e-4));
        assert!(step_gate(Some(1e-4), 1e-4)); // inclusive
    }

    #[test]
    fn from_name_roundtrip() {
        for k in [LambdaKind::Step, LambdaKind::Linear, LambdaKind::Cosine] {
            assert_eq!(LambdaKind::from_name(k.tag()).unwrap(), k);
        }
        assert!(LambdaKind::from_name("sigmoid").is_err());
    }
}
