//! Timestep schedules (paper §2.3, §3.2).
//!
//! Baseline grids ([`baselines`]: EDM ρ-polynomial, linear-σ, cosine,
//! log-SNR), the COS reproduction (score-optimal constant-geodesic-speed,
//! Williams et al. 2024 — [`resample::cos_schedule`]), and the paper's
//! contribution: Wasserstein-bounded adaptive scheduling
//! ([`wasserstein`], Algorithm 1) projected onto a fixed NFE budget by
//! N-step resampling ([`resample`]).
//!
//! Model-free schedules build from `(n, dataset)` alone; pilot-based
//! schedules (COS, SDM) additionally run a small pilot batch through the
//! denoiser. The coordinator caches built schedules per config in the
//! [`cache`] subsystem (single-flight, TTL/LRU, persistence, warm-started
//! pilots — DESIGN.md §6).

pub mod baselines;
pub mod cache;
pub mod pilot;
pub mod resample;
pub mod wasserstein;

pub use baselines::{cosine_schedule, edm_schedule, linear_sigma_schedule, logsnr_schedule};
pub use cache::{CacheConfig, CacheKey, ScheduleCache};
pub use pilot::{pilot_measure, PilotMeasurement};
pub use resample::{cos_schedule, cos_schedule_measured, resample_n_steps};
pub use wasserstein::{wasserstein_schedule, EtaSchedule, WassersteinConfig, WassersteinOutput};

use crate::diffusion::{Param, SigmaGrid};
use crate::model::{DatasetInfo, Denoiser};
use crate::util::Rng;
use crate::Result;

/// Declarative schedule selection (CLI / protocol / experiment configs).
#[derive(Clone, Debug, PartialEq)]
pub enum ScheduleSpec {
    /// EDM ρ-polynomial (eq. 23). The paper's primary baseline.
    Edm { rho: f64 },
    /// σ linear from σ_max to σ_min.
    LinearSigma,
    /// Cosine-shaped log-σ interpolation (Nichol & Dhariwal style).
    Cosine,
    /// Geometric σ spacing (uniform in log-SNR).
    LogSnr,
    /// Corrector-Optimized Schedule baseline (Williams et al., 2024):
    /// pilot-measured incremental cost equalized at constant geodesic
    /// speed (w ≡ 1).
    Cos { pilot_mult: usize, pilot_rows: usize },
    /// SDM adaptive scheduling (§3.2): Algorithm 1 under the η-schedule
    /// (eq. 16) followed by N-step resampling (eqs. 17–22).
    Sdm { eta_min: f64, eta_max: f64, p: f64, q: f64, pilot_rows: usize },
}

impl ScheduleSpec {
    /// Short tag used in table rows and cache keys.
    ///
    /// Every schedule-affecting field must appear here: the coordinator's
    /// schedule cache and the batcher's group key both key on this string,
    /// so omitting a field (as `Cos` and `Sdm { pilot_rows }` once did)
    /// silently aliases differently-configured pilots to one cached grid.
    pub fn tag(&self) -> String {
        match self {
            ScheduleSpec::Edm { rho } => format!("edm(rho={rho})"),
            ScheduleSpec::LinearSigma => "linear".into(),
            ScheduleSpec::Cosine => "cosine".into(),
            ScheduleSpec::LogSnr => "logsnr".into(),
            ScheduleSpec::Cos { pilot_mult, pilot_rows } => {
                format!("cos(m={pilot_mult},r={pilot_rows})")
            }
            ScheduleSpec::Sdm { eta_min, eta_max, p, q, pilot_rows } => {
                format!("sdm(eta={eta_min}..{eta_max},p={p},q={q},r={pilot_rows})")
            }
        }
    }

    /// Does building this schedule require pilot model evaluations?
    pub fn needs_pilot(&self) -> bool {
        matches!(self, ScheduleSpec::Cos { .. } | ScheduleSpec::Sdm { .. })
    }

    /// Calibrated defaults for the SDM schedule (our Table-3 grid search;
    /// EXPERIMENTS.md §Calibration). Like the paper's Table 3, the
    /// operating point depends on the parameterization: VE trajectories
    /// want the paper-scale tolerances with low-σ emphasis (q = 0.25),
    /// while VP/EDM trajectories on these workloads want tighter budgets
    /// and uniform geodesic weighting (q = 0).
    pub fn sdm_defaults(dataset: &str, param: crate::diffusion::Param) -> ScheduleSpec {
        use crate::diffusion::Param;
        // the calibration split is purely by parameterization — every
        // dataset shares the VP/EDM operating point (the old per-dataset
        // arms were duplicates)
        let (eta_min, eta_max, p, q) = match (param, dataset) {
            (Param::Ve, _) => (0.01, 0.40, 1.0, 0.25),
            (_, _) => (0.0005, 0.02, 1.0, 0.0),
        };
        ScheduleSpec::Sdm { eta_min, eta_max, p, q, pilot_rows: 128 }
    }

    /// Build the σ grid with `n` knots in [σ_max, σ_min] (+ final 0).
    ///
    /// `model`/`rng` are only touched by pilot-based schedules.
    pub fn build(
        &self,
        n: usize,
        ds: &DatasetInfo,
        param: Param,
        model: &dyn Denoiser,
        rng: &mut Rng,
    ) -> Result<SigmaGrid> {
        Ok(self.build_with(n, ds, param, model, rng, None)?.grid)
    }

    /// Like [`ScheduleSpec::build`], but returns the full build record
    /// (grid + pilot traces + pilot NFE) and accepts an optional
    /// warm-start schedule: a cached build for a neighboring step budget
    /// of the *same* (dataset, parameterization, spec) whose σ knots seed
    /// Algorithm 1's NEXTTIMESTEP reference grid, cutting the pilot's
    /// LINESEARCH evaluations. Warm starting only affects SDM builds;
    /// every other variant ignores it.
    pub fn build_with(
        &self,
        n: usize,
        ds: &DatasetInfo,
        param: Param,
        model: &dyn Denoiser,
        rng: &mut Rng,
        warm: Option<&BuiltSchedule>,
    ) -> Result<BuiltSchedule> {
        anyhow::ensure!(n >= 2, "need at least 2 schedule knots");
        let model_free = |grid: Result<SigmaGrid>| {
            grid.map(|grid| BuiltSchedule {
                grid,
                raw_sigmas: Vec::new(),
                eta: Vec::new(),
                s_hat: Vec::new(),
                pilot_nfe: 0,
            })
        };
        match self {
            ScheduleSpec::Edm { rho } => {
                model_free(edm_schedule(n, ds.sigma_min, ds.sigma_max, *rho))
            }
            ScheduleSpec::LinearSigma => {
                model_free(linear_sigma_schedule(n, ds.sigma_min, ds.sigma_max))
            }
            ScheduleSpec::Cosine => model_free(cosine_schedule(n, ds.sigma_min, ds.sigma_max)),
            ScheduleSpec::LogSnr => model_free(logsnr_schedule(n, ds.sigma_min, ds.sigma_max)),
            ScheduleSpec::Cos { pilot_mult, pilot_rows } => {
                let (grid, pilot_nfe) =
                    cos_schedule_measured(n, ds, param, model, rng, *pilot_mult, *pilot_rows)?;
                Ok(BuiltSchedule {
                    grid,
                    raw_sigmas: Vec::new(),
                    eta: Vec::new(),
                    s_hat: Vec::new(),
                    pilot_nfe,
                })
            }
            ScheduleSpec::Sdm { eta_min, eta_max, p, q, pilot_rows } => {
                let cfg = WassersteinConfig {
                    eta: EtaSchedule {
                        eta_min: *eta_min,
                        eta_max: *eta_max,
                        p: *p,
                        sigma_max: ds.sigma_max,
                    },
                    // seed NEXTTIMESTEP from the neighbor's *raw committed*
                    // pilot knots: those are the ones Algorithm 1 accepted
                    // near Δt_max, so the line search starts near
                    // acceptance. The resampled grid is q-warped to a step
                    // budget and would seed over/under-bold trials.
                    ref_sigmas: warm.and_then(|w| {
                        (w.raw_sigmas.len() >= 2).then(|| w.raw_sigmas.clone())
                    }),
                    ..WassersteinConfig::default()
                };
                let out = wasserstein_schedule(ds, param, model, rng, &cfg, *pilot_rows)?;
                let grid = resample_n_steps(&out.sigmas, &out.eta, n, *q, ds.sigma_max)?;
                Ok(BuiltSchedule {
                    grid,
                    raw_sigmas: out.sigmas,
                    eta: out.eta,
                    s_hat: out.s_hat,
                    pilot_nfe: out.pilot_nfe,
                })
            }
        }
    }
}

/// One completed schedule build: the N-knot grid plus Algorithm 1's raw
/// output — the committed variable-length σ knots (`raw_sigmas`, which
/// future neighboring builds warm-start from) and the per-interval
/// achieved η_i / Ŝ_i traces (lengths follow `raw_sigmas`, not the
/// resampled grid; all empty for model-free and COS builds) — plus the
/// pilot NFE spent. This is the unit the schedule cache stores, persists,
/// and warm-starts from.
#[derive(Clone, Debug)]
pub struct BuiltSchedule {
    pub grid: SigmaGrid,
    pub raw_sigmas: Vec<f64>,
    pub eta: Vec<f64>,
    pub s_hat: Vec<f64>,
    pub pilot_nfe: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags_are_stable() {
        assert_eq!(ScheduleSpec::Edm { rho: 7.0 }.tag(), "edm(rho=7)");
        assert!(ScheduleSpec::sdm_defaults("cifar10g", Param::vp()).tag().starts_with("sdm("));
    }

    #[test]
    fn tags_do_not_alias_across_pilot_configs() {
        // regression: `Cos { .. }` used to serialize to a bare "cos" and
        // `Sdm` omitted pilot_rows, so specs with different pilot configs
        // collided on one cache key and one batcher group
        let cos_a = ScheduleSpec::Cos { pilot_mult: 4, pilot_rows: 128 };
        let cos_b = ScheduleSpec::Cos { pilot_mult: 8, pilot_rows: 128 };
        let cos_c = ScheduleSpec::Cos { pilot_mult: 4, pilot_rows: 64 };
        assert_ne!(cos_a.tag(), cos_b.tag());
        assert_ne!(cos_a.tag(), cos_c.tag());
        assert_eq!(cos_a.tag(), ScheduleSpec::Cos { pilot_mult: 4, pilot_rows: 128 }.tag());

        let sdm = |pilot_rows| ScheduleSpec::Sdm {
            eta_min: 0.02,
            eta_max: 0.2,
            p: 1.0,
            q: 0.25,
            pilot_rows,
        };
        assert_ne!(sdm(128).tag(), sdm(16).tag());
        assert_eq!(sdm(128).tag(), sdm(128).tag());
    }

    #[test]
    fn pilot_flag() {
        assert!(!ScheduleSpec::Edm { rho: 7.0 }.needs_pilot());
        assert!(ScheduleSpec::sdm_defaults("ffhqg", Param::Ve).needs_pilot());
        assert!(ScheduleSpec::Cos { pilot_mult: 4, pilot_rows: 128 }.needs_pilot());
    }
}
