//! The integration loop (EDM Algorithm-1 shaped, extended with the SDM
//! adaptive solver gate, η̂/κ̂ tracing, and segmented sampling plans).
//!
//! One [`run_plan`] call integrates a whole batch from the prior at σ_max
//! down to σ = 0, dispatching each σ segment of a
//! [`crate::sampler::SamplingPlan`] to its own solver. A single-segment
//! plan is the classic single-solver loop — [`run_sampler`] wraps it and
//! stays bit-identical to the pre-plan engine (pinned by
//! rust/tests/kernel_parity.rs). The per-interval solver decision is
//! batch-aggregate (the paper's curvature profile, Fig. 2, is tight
//! across samples at a given σ, so gating per batch matches how the
//! schedule-level decision is meant to work); NFE is therefore the number
//! of model calls, identically the per-sample NFE.
//!
//! Segment-boundary semantics (DESIGN.md §9): multistep history
//! (Dpm2m's cached data-prediction) is *reset* at every boundary — the
//! incoming solver must not consume a D value produced under a different
//! integration rule. The κ̂/η̂ diagnostics *carry* across fixed-solver
//! boundaries (they describe the trajectory, not the solver), and are
//! reset around a PID segment, which leaves the knot grid entirely.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::diffusion::{kappa_hat_rel, Param, SigmaGrid};
use crate::model::{
    class_mask_row, eval_at_into, uncond_mask_row, DatasetInfo, Denoiser, EvalScratch,
    KernelPrecision, MaskRef,
};
use crate::sampler::plan::SamplingPlan;
use crate::solvers::{
    adaptive, dpm2m::Dpm2mState, euler, heun, LambdaKind, PidParams, PidStepController, SolverSpec,
};
use crate::util::{Rng, ThreadPool};
use crate::Result;

/// Per-run options.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// batch rows integrated together.
    pub rows: usize,
    pub seed: u64,
    /// conditional class (None = unconditional).
    pub class: Option<usize>,
    /// record per-step trace (κ̂, η̂, solver decisions).
    pub trace: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig { rows: 64, seed: 0, class: None, trace: false }
    }
}

/// Cooperative mid-sample cancellation (DESIGN.md §13): a shared flag the
/// engine polls **once per solver step** (a single atomic load; with no
/// token installed the check is a branch on a `None`). Tripping it makes
/// the run return a partial [`RunResult`] with `cancelled: true` at the
/// next step boundary — per-segment NFE attribution stays exact, and the
/// evals *not* spent are estimated into `nfe_refunded`.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken(Arc::new(AtomicBool::new(false)))
    }

    /// Trip the token. Idempotent; every clone observes it.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    /// The per-step check: one relaxed atomic load.
    #[inline]
    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// One per-step progress report delivered to an installed [`RunCtl`]
/// hook: enough for a streaming front-end to render a live trajectory
/// (step counter, σ left to integrate, NFE spent so far, and an optional
/// downsampled first-row preview of the current state x_t).
#[derive(Clone, Debug)]
pub struct StepProgress {
    /// 1-based count of completed solver steps across all segments.
    pub step: usize,
    /// index of the plan segment that produced this step.
    pub segment: usize,
    /// σ level reached after this step (0 when the trajectory is closed).
    pub sigma_remaining: f64,
    /// model evals spent so far (== per-sample NFE so far).
    pub nfe_spent: usize,
    /// evenly-strided entries of the batch's first row of x_t (empty when
    /// previews are disabled via `preview_dims == 0`).
    pub preview: Vec<f32>,
}

/// Per-step observer installed by a streaming caller. Invoked on the
/// solver thread after each completed step — keep it cheap (the gateway
/// hands the report to an unbounded channel and returns).
pub type ProgressHook = Arc<dyn Fn(StepProgress) + Send + Sync>;

/// Optional run control: cancellation + per-step progress. The default
/// (`RunCtl::default()`) installs neither, and the engine's hot loop then
/// pays only an `Option` branch per step — the no-hook path stays
/// bit-identical to the pre-gateway engine (same pattern as chaos).
#[derive(Clone, Default)]
pub struct RunCtl {
    pub cancel: Option<CancelToken>,
    pub progress: Option<ProgressHook>,
    /// preview entries per progress event, strided across the first row
    /// (0 disables previews; capped at the model dim).
    pub preview_dims: usize,
}

impl RunCtl {
    /// Once-per-step cancellation check: `None` → constant false branch,
    /// `Some` → a single atomic load.
    #[inline]
    fn cancelled(&self) -> bool {
        match &self.cancel {
            Some(t) => t.is_cancelled(),
            None => false,
        }
    }

    /// Deliver one progress report (no-op without a hook).
    fn emit(&self, step: usize, segment: usize, sigma_remaining: f64, nfe: usize, x: &[f32], dim: usize) {
        if let Some(hook) = &self.progress {
            let preview = if self.preview_dims == 0 || x.is_empty() {
                Vec::new()
            } else {
                let want = self.preview_dims.min(dim);
                let stride = (dim / want).max(1);
                x[..dim].iter().step_by(stride).take(want).copied().collect()
            };
            hook(StepProgress { step, segment, sigma_remaining, nfe_spent: nfe, preview });
        }
    }
}

/// Trace entry for one integration interval (or one accepted PID step).
#[derive(Clone, Debug)]
pub struct StepRecord {
    pub sigma: f64,
    pub t: f64,
    /// cache-based curvature κ̂_rel at the interval start (None on i=0).
    pub kappa_hat: Option<f64>,
    /// measured local error proxy η̂ = Δt²/2·Ŝ (None on the final σ→0
    /// interval, where no forward evaluation exists). PID steps record
    /// their normalized embedded-pair error here.
    pub eta_hat: Option<f64>,
    /// Heun contribution this interval (0 = pure Euler, 1 = full Heun).
    pub heun_weight: f64,
    /// model evaluations spent on this interval.
    pub evals: usize,
    /// index of the plan segment that produced this step.
    pub segment: usize,
}

/// Result of one batch integration.
#[derive(Clone, Debug)]
pub struct RunResult {
    /// generated samples at σ=0, row-major [rows, dim].
    pub samples: Vec<f32>,
    /// model calls == per-sample NFE.
    pub nfe: usize,
    /// NFE attributed to each plan segment (sums to `nfe`).
    pub seg_nfe: Vec<usize>,
    /// per-interval trace (empty unless `cfg.trace`).
    pub steps: Vec<StepRecord>,
    /// true when a [`CancelToken`] tripped mid-run: `samples` then holds
    /// the partial state x_t at the abort boundary, `nfe`/`seg_nfe` count
    /// only the evals actually spent, and `nfe_refunded` estimates the
    /// evals the remaining trajectory would have cost.
    pub cancelled: bool,
    /// estimated evals not spent due to cancellation (0 when not
    /// cancelled). Deterministic solvers are counted exactly from the
    /// remaining plan intervals; PID remainders are a 2-evals-per-knot
    /// estimate scaled by the un-traversed λ fraction.
    pub nfe_refunded: f64,
}

/// Build the shared mask row for a run: one `k`-wide logit row that every
/// batch row shares (class bounds checked once, not per batch).
pub fn mask_row_for(class: Option<usize>, ds: &DatasetInfo, k: usize) -> Result<Vec<f32>> {
    match class {
        Some(c) => {
            anyhow::ensure!(c < ds.n_classes, "class {c} out of range");
            Ok(class_mask_row(&ds.classes, c))
        }
        None => Ok(uncond_mask_row(k)),
    }
}

/// Integrate one batch down the given σ grid with a single solver (a
/// one-segment plan).
pub fn run_sampler(
    model: &dyn Denoiser,
    param: Param,
    grid: &SigmaGrid,
    solver: &SolverSpec,
    ds: &DatasetInfo,
    cfg: &RunConfig,
) -> Result<RunResult> {
    run_plan(model, param, grid, &SamplingPlan::single(*solver), ds, cfg)
}

/// [`run_sampler`] with a caller-built shared mask row.
pub fn run_sampler_masked(
    model: &dyn Denoiser,
    param: Param,
    grid: &SigmaGrid,
    solver: &SolverSpec,
    cfg: &RunConfig,
    mask_row: &[f32],
) -> Result<RunResult> {
    run_plan_masked(model, param, grid, &SamplingPlan::single(*solver), cfg, mask_row)
}

/// Integrate one batch down the given σ grid under a segmented plan.
pub fn run_plan(
    model: &dyn Denoiser,
    param: Param,
    grid: &SigmaGrid,
    plan: &SamplingPlan,
    ds: &DatasetInfo,
    cfg: &RunConfig,
) -> Result<RunResult> {
    run_plan_prec(model, param, grid, plan, ds, cfg, KernelPrecision::Exact)
}

/// [`run_plan`] at an explicit kernel precision tier. `Exact` is
/// bit-identical to [`run_plan`]; the fast tiers route eligible native
/// models through the SIMD tile kernel (DESIGN.md §10) — the serving
/// batcher threads each request's wire-selected tier through here.
#[allow(clippy::too_many_arguments)]
pub fn run_plan_prec(
    model: &dyn Denoiser,
    param: Param,
    grid: &SigmaGrid,
    plan: &SamplingPlan,
    ds: &DatasetInfo,
    cfg: &RunConfig,
    precision: KernelPrecision,
) -> Result<RunResult> {
    let mask_row = mask_row_for(cfg.class, ds, model.k())?;
    run_plan_masked_prec(model, param, grid, plan, cfg, &mask_row, precision)
}

/// [`run_plan`] with a caller-built shared mask row — the batched
/// generators build the row once per request and reuse it across every
/// batch/shard instead of materializing a fresh `[rows·k]` mask per
/// batch.
///
/// All per-step buffers live in one [`EvalScratch`] arena owned by the
/// run: model outputs are double-buffered (`cur`/`prev` swap roles each
/// interval; the second in-interval eval lands in `aux`), so after the
/// prior draw the whole integration performs no per-step heap
/// allocation — and with a native-oracle model, none per eval either
/// (§Perf iteration 3, DESIGN.md §7). The one exception is a PID
/// segment, which clones `x` once at entry for its error reference.
pub fn run_plan_masked(
    model: &dyn Denoiser,
    param: Param,
    grid: &SigmaGrid,
    plan: &SamplingPlan,
    cfg: &RunConfig,
    mask_row: &[f32],
) -> Result<RunResult> {
    run_plan_masked_prec(model, param, grid, plan, cfg, mask_row, KernelPrecision::Exact)
}

/// [`run_plan_masked`] at an explicit kernel precision tier: the tier is
/// stamped on the run's own [`EvalScratch`] before the first eval, so it
/// applies to every model call of this batch and nothing outside it.
#[allow(clippy::too_many_arguments)]
pub fn run_plan_masked_prec(
    model: &dyn Denoiser,
    param: Param,
    grid: &SigmaGrid,
    plan: &SamplingPlan,
    cfg: &RunConfig,
    mask_row: &[f32],
    precision: KernelPrecision,
) -> Result<RunResult> {
    run_plan_masked_ctl(model, param, grid, plan, cfg, mask_row, precision, &RunCtl::default())
}

/// [`run_plan_masked_prec`] under a [`RunCtl`]: the streaming entry point.
/// With the default control this is the exact same run — the per-step
/// cancellation check is a branch on `None` and no progress is emitted —
/// so every non-streaming caller delegates here without perturbing the
/// bit-identity contracts (kernel_parity.rs).
#[allow(clippy::too_many_arguments)]
pub fn run_plan_masked_ctl(
    model: &dyn Denoiser,
    param: Param,
    grid: &SigmaGrid,
    plan: &SamplingPlan,
    cfg: &RunConfig,
    mask_row: &[f32],
    precision: KernelPrecision,
    ctl: &RunCtl,
) -> Result<RunResult> {
    let dim = model.dim();
    let rows = cfg.rows;
    anyhow::ensure!(rows > 0, "rows must be positive");
    anyhow::ensure!(
        mask_row.len() == model.k(),
        "mask row has {} entries, model has k={}",
        mask_row.len(),
        model.k()
    );
    plan.validate()?;
    let times = grid.times(param);
    let sigmas = &grid.sigmas;
    let n_int = grid.intervals();

    // solver contracts checked up front, before any RNG draw, so invalid
    // configs fail identically whether or not they would ever be reached
    for seg in &plan.segments {
        if matches!(seg.solver, SolverSpec::StochasticHeun(_)) {
            anyhow::ensure!(
                param == Param::Edm,
                "the stochastic churn sampler is defined for the EDM parameterization"
            );
        }
        if matches!(seg.solver, SolverSpec::Dpm2m) {
            anyhow::ensure!(
                param.s(times[0]) == 1.0,
                "dpm2m operates in the sigma domain and requires s(t) ≡ 1 (EDM/VE)"
            );
        }
    }

    let ranges = plan.segment_ranges(sigmas);
    let mask = MaskRef::Row(mask_row);

    let mut rng = Rng::new(cfg.seed);
    let mut x = vec![0.0f32; rows * dim];
    rng.fill_normal_f32(&mut x, param.prior_std(times[0]));

    let mut scr = EvalScratch::new();
    scr.kernel.set_precision(precision);
    let mut nfe = 0usize;
    let mut seg_nfe = vec![0usize; plan.segments.len()];
    let mut steps: Vec<StepRecord> = Vec::new();
    let mut have_prev = false;
    let mut prev_t = times[0];
    let mut prev_sigma = sigmas[0];
    // pending η̂ measurement: (step index, Δt). The velocity it will be
    // measured against is the interval-start eval already double-buffered
    // in `scr.prev` by the time it resolves — no clone needed.
    let mut pending_eta: Option<(usize, f64)> = None;
    // completed solver steps across all segments (progress-event unit)
    let mut step_no = 0usize;

    for (seg_idx, (seg, &(lo_i, hi_i))) in plan.segments.iter().zip(&ranges).enumerate() {
        if lo_i == hi_i {
            continue;
        }
        let nfe_before = nfe;

        if let SolverSpec::Pid(pid) = &seg.solver {
            // the PID arm free-steps in λ = ln σ off the knot grid, so the
            // knot-indexed κ̂/η̂ diagnostics are reset around it
            pending_eta = None;
            let pid_refund = run_pid_segment(
                model, param, pid, &times, sigmas, lo_i, hi_i, mask, rows, cfg.trace, seg_idx,
                &mut x, &mut scr, &mut nfe, &mut steps, ctl, &mut step_no,
            )?;
            have_prev = false;
            prev_t = times[hi_i];
            prev_sigma = sigmas[hi_i];
            seg_nfe[seg_idx] = nfe - nfe_before;
            if let Some(within) = pid_refund {
                let refunded = within + remaining_nfe_estimate(plan, &ranges, sigmas, seg_idx + 1, 0);
                return Ok(RunResult {
                    samples: x,
                    nfe,
                    seg_nfe,
                    steps,
                    cancelled: true,
                    nfe_refunded: refunded,
                });
            }
            continue;
        }

        let solver = &seg.solver;
        // fresh multistep history per segment: the incoming solver must
        // not consume a D value produced under a different rule
        let mut dpm_state = Dpm2mState::new();

        for i in lo_i..hi_i {
            // once-per-step cancellation gate: a single atomic load when a
            // token is installed, a `None` branch otherwise. Aborting here
            // keeps `seg_nfe` attribution exact for the work already done.
            if ctl.cancelled() {
                seg_nfe[seg_idx] = nfe - nfe_before;
                let refunded = remaining_nfe_estimate(plan, &ranges, sigmas, seg_idx, i);
                return Ok(RunResult {
                    samples: x,
                    nfe,
                    seg_nfe,
                    steps,
                    cancelled: true,
                    nfe_refunded: refunded,
                });
            }
            let (mut t_i, t_next) = (times[i], times[i + 1]);
            let (mut sigma_i, sigma_next) = (sigmas[i], sigmas[i + 1]);

            // stochastic churn (EDM param: t == σ)
            if let SolverSpec::StochasticHeun(churn) = solver {
                let sigma_hat = churn.churn(&mut x, sigma_i, n_int, &mut rng);
                sigma_i = sigma_hat;
                t_i = sigma_hat;
            }

            // v_i at the (possibly churned) interval start → scr.cur
            // (scr.prev still holds the previous interval's eval)
            eval_at_into(model, param, &x, t_i, mask, rows, &mut scr.xhat, &mut scr.kernel, &mut scr.cur)?;
            nfe += 1;

            // resolve the η̂ of the previous interval with this fresh eval
            if let Some((idx, dt_then)) = pending_eta.take() {
                if cfg.trace {
                    let s_hat = mean_dv_norm(&scr.prev.v, &scr.cur.v, rows, dim) / dt_then.max(1e-30);
                    steps[idx].eta_hat = Some(0.5 * dt_then * dt_then * s_hat);
                }
            }

            // cache-based curvature κ̂ (eq. 8) from the previous interval's v
            let kappa = if have_prev {
                let clock = match solver {
                    SolverSpec::Adaptive { clock, .. } => *clock,
                    _ => crate::diffusion::CurvatureClock::Sigma,
                };
                let delta = clock.delta(prev_t, t_i, prev_sigma, sigma_i);
                Some(kappa_hat_rel(&scr.prev.v, &scr.cur.v, rows, dim, delta))
            } else {
                None
            };

            let dt = t_next - t_i;
            let step_idx = steps.len();
            let mut evals_this = 1usize;
            let mut heun_weight = 0.0f64;
            // η̂ measured directly when this interval spends a second eval
            let mut eta_now: Option<f64> = None;
            // measure η̂ = Δt²/2·Ŝ from the two velocities bracketing the step
            let measure_eta = |v0: &[f32], v1: &[f32]| -> f64 {
                let dt_abs = dt.abs().max(1e-30);
                let s_hat = mean_dv_norm(v0, v1, rows, dim) / dt_abs;
                0.5 * dt_abs * dt_abs * s_hat
            };

            match solver {
                SolverSpec::Pid(_) => unreachable!("pid segments are handled above"),
                SolverSpec::Euler => {
                    euler::euler_step(&mut x, &scr.cur.v, dt);
                }
                SolverSpec::Dpm2m => {
                    dpm_state.step(&mut x, &scr.cur.d, sigma_i, sigma_next);
                }
                SolverSpec::Heun | SolverSpec::StochasticHeun(_) => {
                    euler::euler_step_to(&x, &scr.cur.v, dt, &mut scr.euler_x);
                    if sigma_next > 0.0 {
                        eval_at_into(
                            model,
                            param,
                            &scr.euler_x,
                            t_next,
                            mask,
                            rows,
                            &mut scr.xhat,
                            &mut scr.kernel,
                            &mut scr.aux,
                        )?;
                        nfe += 1;
                        evals_this += 1;
                        heun_weight = 1.0;
                        heun::heun_correct(&mut x, &scr.cur.v, &scr.aux.v, dt);
                        if cfg.trace {
                            eta_now = Some(measure_eta(&scr.cur.v, &scr.aux.v));
                        }
                    } else {
                        x.copy_from_slice(&scr.euler_x);
                    }
                }
                SolverSpec::Adaptive { lambda, tau_k, .. } => {
                    euler::euler_step_to(&x, &scr.cur.v, dt, &mut scr.euler_x);
                    let last = sigma_next <= 0.0;
                    let use_heun = match lambda {
                        LambdaKind::Step => !last && adaptive::step_gate(kappa, *tau_k),
                        _ => !last,
                    };
                    if use_heun {
                        eval_at_into(
                            model,
                            param,
                            &scr.euler_x,
                            t_next,
                            mask,
                            rows,
                            &mut scr.xhat,
                            &mut scr.kernel,
                            &mut scr.aux,
                        )?;
                        nfe += 1;
                        evals_this += 1;
                        let lam = match lambda {
                            LambdaKind::Step => 0.0, // pure Heun once gated
                            k => k.lambda(i, n_int),
                        };
                        heun_weight = 1.0 - lam;
                        if lam == 0.0 {
                            // step-Λ gated interval == pure Heun: correct in
                            // place, no blend buffer (§Perf iteration 2)
                            heun::heun_correct(&mut x, &scr.cur.v, &scr.aux.v, dt);
                        } else {
                            // x^H from the predictor pair staged in the arena
                            // (no per-step x.clone()), then blend (eq. 9)
                            scr.blend_x.clear();
                            scr.blend_x.extend_from_slice(&x);
                            heun::heun_correct(&mut scr.blend_x, &scr.cur.v, &scr.aux.v, dt);
                            adaptive::blend(&scr.euler_x, &scr.blend_x, lam, &mut x);
                        }
                        if cfg.trace {
                            eta_now = Some(measure_eta(&scr.cur.v, &scr.aux.v));
                        }
                    } else {
                        x.copy_from_slice(&scr.euler_x);
                    }
                }
            }

            if cfg.trace {
                steps.push(StepRecord {
                    sigma: sigma_i,
                    t: t_i,
                    kappa_hat: kappa,
                    eta_hat: eta_now,
                    heun_weight,
                    evals: evals_this,
                    segment: seg_idx,
                });
                if eta_now.is_none() && sigma_next > 0.0 {
                    // defer: resolved against scr.prev at the next interval
                    // start (this interval's only eval is about to become
                    // scr.prev in the swap below)
                    pending_eta = Some((step_idx, dt.abs()));
                }
            }

            std::mem::swap(&mut scr.prev, &mut scr.cur);
            have_prev = true;
            prev_t = t_i;
            prev_sigma = sigma_i;
            step_no += 1;
            ctl.emit(step_no, seg_idx, sigma_next, nfe, &x, dim);
        }

        seg_nfe[seg_idx] = nfe - nfe_before;
    }

    Ok(RunResult { samples: x, nfe, seg_nfe, steps, cancelled: false, nfe_refunded: 0.0 })
}

/// Estimated eval cost of one grid interval under a solver: the exact
/// deterministic cost for the fixed-order solvers (second-order families
/// spend 1 on the final σ→0 interval, 2 elsewhere), and a per-knot
/// estimate of 2 for the PID arm, whose true cost depends on its
/// accept/reject trajectory.
fn interval_cost(solver: &SolverSpec, is_final: bool) -> f64 {
    match solver {
        SolverSpec::Euler | SolverSpec::Dpm2m => 1.0,
        SolverSpec::Heun | SolverSpec::StochasticHeun(_) | SolverSpec::Adaptive { .. } => {
            if is_final {
                1.0
            } else {
                2.0
            }
        }
        SolverSpec::Pid(_) => 2.0,
    }
}

/// Estimated evals left in a plan from interval `i_from` of segment
/// `seg_from` to σ = 0 — the NFE a cancellation refunds. Adaptive
/// segments are costed at their 2-eval ceiling (the refund is an upper
/// estimate of saved work, used for accounting, never for scheduling).
fn remaining_nfe_estimate(
    plan: &SamplingPlan,
    ranges: &[(usize, usize)],
    sigmas: &[f64],
    seg_from: usize,
    i_from: usize,
) -> f64 {
    let mut est = 0.0;
    for (seg_idx, (seg, &(lo_i, hi_i))) in plan.segments.iter().zip(ranges).enumerate() {
        if seg_idx < seg_from {
            continue;
        }
        let start = if seg_idx == seg_from { i_from.max(lo_i) } else { lo_i };
        for i in start..hi_i {
            est += interval_cost(&seg.solver, sigmas[i + 1] <= 0.0);
        }
    }
    est
}

/// Estimated full-run NFE of a plan over a σ grid — the refund a request
/// cancelled *before* its first solver step is credited with.
pub fn plan_nfe_estimate(plan: &SamplingPlan, sigmas: &[f64]) -> f64 {
    let ranges = plan.segment_ranges(sigmas);
    remaining_nfe_estimate(plan, &ranges, sigmas, 0, 0)
}

/// One PID-controlled segment: an embedded Euler/Heun pair stepped freely
/// in λ = ln σ under accept/reject control (k-diffusion's
/// `sample_dpm_adaptive` shape, ported to this engine's σ-domain arena).
/// Adapts from `sigmas[lo_i]` down to the last *positive* knot of the
/// segment; when the segment ends at σ = 0 a final uncontrolled Euler
/// step closes the trajectory (the embedded pair needs a positive σ).
#[allow(clippy::too_many_arguments)]
fn run_pid_segment(
    model: &dyn Denoiser,
    param: Param,
    pid: &PidParams,
    times: &[f64],
    sigmas: &[f64],
    lo_i: usize,
    hi_i: usize,
    mask: MaskRef,
    rows: usize,
    trace: bool,
    seg_idx: usize,
    x: &mut Vec<f32>,
    scr: &mut EvalScratch,
    nfe: &mut usize,
    steps: &mut Vec<StepRecord>,
    ctl: &RunCtl,
    step_no: &mut usize,
) -> Result<Option<f64>> {
    let ends_at_zero = sigmas[hi_i] <= 0.0;
    let floor_idx = if ends_at_zero { hi_i - 1 } else { hi_i };

    if floor_idx > lo_i {
        let lam_start = sigmas[lo_i].ln();
        let lam_end = sigmas[floor_idx].ln();
        let mut lam = lam_start;
        let mut ctrl = PidStepController::new(pid, 2);
        // previous accepted low-order solution — the error reference
        let mut x_prev = x.clone();
        let mut rejects = 0usize;
        let mut trials = 0usize;
        while lam > lam_end + 1e-9 {
            // once-per-trial cancellation gate (the PID arm's "step" is a
            // trial). The refund scales the segment's 2-evals-per-knot
            // estimate by the λ span not yet traversed.
            if ctl.cancelled() {
                let span = (lam_start - lam_end).max(1e-30);
                let frac = ((lam - lam_end) / span).clamp(0.0, 1.0);
                let mut within = 2.0 * (hi_i - lo_i) as f64 * frac;
                if ends_at_zero {
                    within += 1.0; // the closing Euler step is also skipped
                }
                return Ok(Some(within));
            }
            trials += 1;
            anyhow::ensure!(
                trials <= 100_000,
                "pid controller failed to traverse its segment within 100k trials"
            );
            let h = ctrl.h.min(lam - lam_end);
            let (sigma_cur, sigma_nxt) = (lam.exp(), (lam - h).exp());
            let (t_cur, t_nxt) = (param.t_of_sigma(sigma_cur), param.t_of_sigma(sigma_nxt));
            let dt = t_nxt - t_cur;
            eval_at_into(model, param, x, t_cur, mask, rows, &mut scr.xhat, &mut scr.kernel, &mut scr.cur)?;
            *nfe += 1;
            // low-order (Euler) trial → scr.euler_x
            euler::euler_step_to(x, &scr.cur.v, dt, &mut scr.euler_x);
            eval_at_into(
                model,
                param,
                &scr.euler_x,
                t_nxt,
                mask,
                rows,
                &mut scr.xhat,
                &mut scr.kernel,
                &mut scr.aux,
            )?;
            *nfe += 1;
            // high-order (Heun) trial → scr.blend_x
            scr.blend_x.clear();
            scr.blend_x.extend_from_slice(x);
            heun::heun_correct(&mut scr.blend_x, &scr.cur.v, &scr.aux.v, dt);
            let error = pid_error(&scr.euler_x, &scr.blend_x, &x_prev, pid.atol, pid.rtol);
            // force-accept after a run of rejects: by then the limiter has
            // shrunk h to where the trial is effectively a no-op
            let accept = ctrl.propose_step(error) || rejects >= 16;
            if accept {
                x_prev.copy_from_slice(&scr.euler_x);
                x.copy_from_slice(&scr.blend_x);
                lam -= h;
                rejects = 0;
                *step_no += 1;
                ctl.emit(*step_no, seg_idx, lam.exp(), *nfe, x, x.len() / rows.max(1));
                if trace {
                    steps.push(StepRecord {
                        sigma: sigma_cur,
                        t: t_cur,
                        kappa_hat: None,
                        eta_hat: Some(error),
                        heun_weight: 1.0,
                        evals: 2,
                        segment: seg_idx,
                    });
                }
            } else {
                rejects += 1;
            }
        }
    }

    if ends_at_zero {
        if ctl.cancelled() {
            return Ok(Some(1.0)); // only the closing Euler eval remains
        }
        let (t_floor, t_zero) = (times[hi_i - 1], times[hi_i]);
        eval_at_into(model, param, x, t_floor, mask, rows, &mut scr.xhat, &mut scr.kernel, &mut scr.cur)?;
        *nfe += 1;
        euler::euler_step(x, &scr.cur.v, t_zero - t_floor);
        *step_no += 1;
        ctl.emit(*step_no, seg_idx, 0.0, *nfe, x, x.len() / rows.max(1));
        if trace {
            steps.push(StepRecord {
                sigma: sigmas[hi_i - 1],
                t: t_floor,
                kappa_hat: None,
                eta_hat: None,
                heun_weight: 0.0,
                evals: 1,
                segment: seg_idx,
            });
        }
    }
    Ok(None)
}

/// Normalized embedded-pair error (k-diffusion semantics): RMS over all
/// coordinates of (x_low − x_high)/δ with δ = max(atol, rtol·max(|x_low|,
/// |x_prev|)).
// lint: no-alloc
fn pid_error(x_low: &[f32], x_high: &[f32], x_prev: &[f32], atol: f64, rtol: f64) -> f64 {
    debug_assert_eq!(x_low.len(), x_high.len());
    debug_assert_eq!(x_low.len(), x_prev.len());
    let mut acc = 0.0f64;
    for i in 0..x_low.len() {
        let lo = x_low[i] as f64;
        let hi = x_high[i] as f64;
        let pv = x_prev[i] as f64;
        let delta = atol.max(rtol * lo.abs().max(pv.abs()));
        let e = (lo - hi) / delta.max(1e-30);
        acc += e * e;
    }
    (acc / x_low.len().max(1) as f64).sqrt()
}

// lint: no-alloc
fn mean_dv_norm(v_prev: &[f32], v_cur: &[f32], rows: usize, dim: usize) -> f64 {
    let mut total = 0.0f64;
    for r in 0..rows {
        let mut dv2 = 0.0f64;
        for c in 0..dim {
            let d = (v_cur[r * dim + c] - v_prev[r * dim + c]) as f64;
            dv2 += d * d;
        }
        total += dv2.sqrt();
    }
    total / rows as f64
}

/// Generate `total` samples in batches of `cfg.rows`, forking the seed per
/// batch. Returns (samples [total, dim], mean NFE per batch, trace of the
/// first batch).
pub fn generate(
    model: &dyn Denoiser,
    param: Param,
    grid: &SigmaGrid,
    solver: &SolverSpec,
    ds: &DatasetInfo,
    cfg: &RunConfig,
    total: usize,
) -> Result<(Vec<f32>, f64, Vec<StepRecord>)> {
    let (samples, nfe, trace, _) =
        generate_plan(model, param, grid, &SamplingPlan::single(*solver), ds, cfg, total)?;
    Ok((samples, nfe, trace))
}

/// Plan-aware [`generate`]: additionally returns the mean per-segment NFE
/// (one entry per plan segment, summing to the mean NFE).
pub fn generate_plan(
    model: &dyn Denoiser,
    param: Param,
    grid: &SigmaGrid,
    plan: &SamplingPlan,
    ds: &DatasetInfo,
    cfg: &RunConfig,
    total: usize,
) -> Result<(Vec<f32>, f64, Vec<StepRecord>, Vec<f64>)> {
    generate_plan_prec(model, param, grid, plan, ds, cfg, total, KernelPrecision::Exact)
}

/// [`generate_plan`] at an explicit kernel precision tier (every batch of
/// the request runs at the same tier).
#[allow(clippy::too_many_arguments)]
pub fn generate_plan_prec(
    model: &dyn Denoiser,
    param: Param,
    grid: &SigmaGrid,
    plan: &SamplingPlan,
    ds: &DatasetInfo,
    cfg: &RunConfig,
    total: usize,
    precision: KernelPrecision,
) -> Result<(Vec<f32>, f64, Vec<StepRecord>, Vec<f64>)> {
    let (samples, nfe, trace, seg, _) =
        generate_plan_ctl(model, param, grid, plan, ds, cfg, total, precision, &RunCtl::default())?;
    Ok((samples, nfe, trace, seg))
}

/// [`generate_plan_prec`] under a [`RunCtl`]. The extra return is the
/// cancellation outcome: `None` when the request ran to completion,
/// `Some(nfe_refunded)` when the token tripped — the samples generated so
/// far are returned (whole completed batches plus the partial state of
/// the batch that aborted), and batches never started are refunded at the
/// plan's full estimated cost.
#[allow(clippy::too_many_arguments)]
pub fn generate_plan_ctl(
    model: &dyn Denoiser,
    param: Param,
    grid: &SigmaGrid,
    plan: &SamplingPlan,
    ds: &DatasetInfo,
    cfg: &RunConfig,
    total: usize,
    precision: KernelPrecision,
    ctl: &RunCtl,
) -> Result<(Vec<f32>, f64, Vec<StepRecord>, Vec<f64>, Option<f64>)> {
    let dim = model.dim();
    // one shared mask row for every batch of the request
    let mask_row = mask_row_for(cfg.class, ds, model.k())?;
    let mut samples = Vec::with_capacity(total * dim);
    let mut nfes = Vec::new();
    let mut seg_acc = vec![0.0f64; plan.segments.len()];
    let mut first_trace = Vec::new();
    let mut remaining = total;
    let mut batch_idx = 0u64;
    let mut refunded: Option<f64> = None;
    while remaining > 0 {
        let rows = remaining.min(cfg.rows);
        let bcfg = RunConfig {
            rows,
            seed: cfg.seed.wrapping_add(batch_idx.wrapping_mul(0x9E37_79B9)),
            class: cfg.class,
            trace: cfg.trace && batch_idx == 0,
        };
        let out = run_plan_masked_ctl(model, param, grid, plan, &bcfg, &mask_row, precision, ctl)?;
        samples.extend_from_slice(&out.samples);
        nfes.push(out.nfe as f64);
        for (a, s) in seg_acc.iter_mut().zip(&out.seg_nfe) {
            *a += *s as f64;
        }
        if batch_idx == 0 {
            first_trace = out.steps;
        }
        remaining -= rows;
        batch_idx += 1;
        if out.cancelled {
            // batches never started refund at the plan's full estimate
            let per_batch = plan_nfe_estimate(plan, &grid.sigmas);
            let skipped = (remaining + cfg.rows - 1) / cfg.rows.max(1);
            refunded = Some(out.nfe_refunded + skipped as f64 * per_batch);
            break;
        }
    }
    let n_batches = nfes.len().max(1) as f64;
    for a in &mut seg_acc {
        *a /= n_batches;
    }
    Ok((samples, crate::util::mean(&nfes), first_trace, seg_acc, refunded))
}

/// Per-shard state of a pooled [`generate_pooled_plan`] run.
struct ShardState {
    done: usize,
    slots: Vec<Option<Result<RunResult>>>,
}

/// Row-sharded [`generate`]: bit-identical output (same per-batch forked
/// seeds, same assembly order, same mean-NFE arithmetic), but the batches
/// execute concurrently on the shared worker pool.
#[allow(clippy::too_many_arguments)]
pub fn generate_pooled(
    model: &Arc<dyn Denoiser>,
    param: Param,
    grid: &SigmaGrid,
    solver: &SolverSpec,
    ds: &DatasetInfo,
    cfg: &RunConfig,
    total: usize,
    pool: &ThreadPool,
) -> Result<(Vec<f32>, f64, Vec<StepRecord>)> {
    let (samples, nfe, trace, _) = generate_pooled_plan(
        model,
        param,
        grid,
        &SamplingPlan::single(*solver),
        ds,
        cfg,
        total,
        pool,
    )?;
    Ok((samples, nfe, trace))
}

/// Row-sharded [`generate_plan`]: bit-identical output (same per-batch
/// forked seeds, same assembly order, same mean-NFE arithmetic), but the
/// batches execute concurrently on the shared worker pool.
///
/// Scheduling is **help-first**: the caller claims and integrates shards
/// itself while offering the remainder to the pool, so calling this from
/// *inside* a pool job (the batcher's flush path, a config-sweep worker)
/// can never deadlock — even a fully saturated pool makes progress
/// through the caller, and helper jobs that arrive late simply find the
/// shard counter exhausted and exit.
#[allow(clippy::too_many_arguments)]
pub fn generate_pooled_plan(
    model: &Arc<dyn Denoiser>,
    param: Param,
    grid: &SigmaGrid,
    plan: &SamplingPlan,
    ds: &DatasetInfo,
    cfg: &RunConfig,
    total: usize,
    pool: &ThreadPool,
) -> Result<(Vec<f32>, f64, Vec<StepRecord>, Vec<f64>)> {
    generate_pooled_plan_prec(model, param, grid, plan, ds, cfg, total, pool, KernelPrecision::Exact)
}

/// [`generate_pooled_plan`] at an explicit kernel precision tier: every
/// shard's worker stamps the tier on its own [`EvalScratch`], so a pooled
/// fast-tier run never leaks precision into other jobs sharing the pool.
#[allow(clippy::too_many_arguments)]
pub fn generate_pooled_plan_prec(
    model: &Arc<dyn Denoiser>,
    param: Param,
    grid: &SigmaGrid,
    plan: &SamplingPlan,
    ds: &DatasetInfo,
    cfg: &RunConfig,
    total: usize,
    pool: &ThreadPool,
    precision: KernelPrecision,
) -> Result<(Vec<f32>, f64, Vec<StepRecord>, Vec<f64>)> {
    let (samples, nfe, trace, seg, _) = generate_pooled_plan_ctl(
        model,
        param,
        grid,
        plan,
        ds,
        cfg,
        total,
        pool,
        precision,
        &RunCtl::default(),
    )?;
    Ok((samples, nfe, trace, seg))
}

/// [`generate_pooled_plan_prec`] under a [`RunCtl`]: every shard polls the
/// same token (a shard that starts after the trip aborts at its first
/// step and refunds its whole estimate), and the per-shard refunds sum
/// into the returned `Some(nfe_refunded)`.
#[allow(clippy::too_many_arguments)]
pub fn generate_pooled_plan_ctl(
    model: &Arc<dyn Denoiser>,
    param: Param,
    grid: &SigmaGrid,
    plan: &SamplingPlan,
    ds: &DatasetInfo,
    cfg: &RunConfig,
    total: usize,
    pool: &ThreadPool,
    precision: KernelPrecision,
    ctl: &RunCtl,
) -> Result<(Vec<f32>, f64, Vec<StepRecord>, Vec<f64>, Option<f64>)> {
    anyhow::ensure!(cfg.rows > 0, "rows must be positive");
    if total == 0 {
        return Ok((Vec::new(), 0.0, Vec::new(), vec![0.0; plan.segments.len()], None));
    }
    let batch_rows = cfg.rows;
    let n_batches = (total + batch_rows - 1) / batch_rows;
    // one shared mask row built up front and shared by every shard
    // (previously each shard rebuilt a full [rows·k] mask)
    let mask_row: Arc<Vec<f32>> = Arc::new(mask_row_for(cfg.class, ds, model.k())?);

    let shared = Arc::new((
        Mutex::new(ShardState {
            done: 0,
            slots: (0..n_batches).map(|_| None).collect(),
        }),
        Condvar::new(),
    ));
    let next = Arc::new(AtomicUsize::new(0));

    let worker: Arc<dyn Fn() + Send + Sync> = {
        let model = Arc::clone(model);
        let grid = grid.clone();
        let plan = plan.clone();
        let cfg = cfg.clone();
        let mask_row = Arc::clone(&mask_row);
        let shared = Arc::clone(&shared);
        let next = Arc::clone(&next);
        let ctl = ctl.clone();
        Arc::new(move || loop {
            let i = next.fetch_add(1, Ordering::SeqCst);
            if i >= n_batches {
                break;
            }
            let rows_i = batch_rows.min(total - i * batch_rows);
            let bcfg = RunConfig {
                rows: rows_i,
                seed: cfg.seed.wrapping_add((i as u64).wrapping_mul(0x9E37_79B9)),
                class: cfg.class,
                trace: cfg.trace && i == 0,
            };
            let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                run_plan_masked_ctl(
                    model.as_ref(),
                    param,
                    &grid,
                    &plan,
                    &bcfg,
                    &mask_row,
                    precision,
                    &ctl,
                )
            }))
            .unwrap_or_else(|_| Err(anyhow::anyhow!("generation batch {i} panicked")));
            let (lock, cv) = &*shared;
            let mut st = lock.lock().expect("shard state poisoned");
            st.slots[i] = Some(out);
            st.done += 1;
            cv.notify_all();
        })
    };

    // the caller takes a share of the work itself, so never hand the pool
    // more helpers than there are *other* shards
    let helpers = pool.threads().min(n_batches.saturating_sub(1));
    for _ in 0..helpers {
        let w = Arc::clone(&worker);
        pool.execute(move || (*w)());
    }
    (*worker)();

    let slots = {
        let (lock, cv) = &*shared;
        let mut st = lock.lock().expect("shard state poisoned");
        while st.done < n_batches {
            st = cv.wait(st).expect("shard state poisoned");
        }
        std::mem::take(&mut st.slots)
    };

    let dim = model.dim();
    let mut samples = Vec::with_capacity(total * dim);
    let mut nfes = Vec::with_capacity(n_batches);
    let mut seg_acc = vec![0.0f64; plan.segments.len()];
    let mut first_trace = Vec::new();
    let mut refund_sum = 0.0f64;
    let mut any_cancelled = false;
    for (i, slot) in slots.into_iter().enumerate() {
        let out = slot.expect("all shards accounted for")?;
        samples.extend_from_slice(&out.samples);
        nfes.push(out.nfe as f64);
        for (a, s) in seg_acc.iter_mut().zip(&out.seg_nfe) {
            *a += *s as f64;
        }
        if i == 0 {
            first_trace = out.steps;
        }
        if out.cancelled {
            any_cancelled = true;
            refund_sum += out.nfe_refunded;
        }
    }
    for a in &mut seg_acc {
        *a /= n_batches as f64;
    }
    let refunded = if any_cancelled { Some(refund_sum) } else { None };
    Ok((samples, crate::util::mean(&nfes), first_trace, seg_acc, refunded))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gmm::testmodel::toy;
    use crate::schedule::baselines::edm_schedule;
    use crate::solvers::PidParams;

    fn setup() -> (crate::model::GmmModel, DatasetInfo, SigmaGrid) {
        let m = toy();
        let ds = m.info.clone();
        let grid = edm_schedule(24, ds.sigma_min, ds.sigma_max, ds.rho).unwrap();
        (m, ds, grid)
    }

    fn fd_of(samples: &[f32], ds: &DatasetInfo) -> f64 {
        let stats = crate::metrics::sample_mean_cov(samples, ds.dim);
        crate::metrics::frechet_to_reference(&stats, &ds.exact_mean, &ds.exact_cov).unwrap()
    }

    #[test]
    fn euler_nfe_equals_intervals() {
        let (m, ds, grid) = setup();
        let cfg = RunConfig { rows: 32, seed: 1, class: None, trace: false };
        let out = run_sampler(&m, Param::Edm, &grid, &SolverSpec::Euler, &ds, &cfg).unwrap();
        assert_eq!(out.nfe, grid.intervals());
        assert_eq!(out.samples.len(), 32 * ds.dim);
        assert!(out.samples.iter().all(|v| v.is_finite()));
        // single-segment attribution: all NFE on segment 0
        assert_eq!(out.seg_nfe, vec![grid.intervals()]);
    }

    #[test]
    fn heun_nfe_is_two_per_interval_minus_final() {
        let (m, ds, grid) = setup();
        let cfg = RunConfig { rows: 16, seed: 2, ..Default::default() };
        let out = run_sampler(&m, Param::Edm, &grid, &SolverSpec::Heun, &ds, &cfg).unwrap();
        assert_eq!(out.nfe, 2 * grid.intervals() - 1);
    }

    #[test]
    fn heun_beats_euler_in_quality() {
        let (m, ds, grid) = setup();
        let cfg = RunConfig { rows: 256, seed: 3, ..Default::default() };
        let (se, _, _) =
            generate(&m, Param::Edm, &grid, &SolverSpec::Euler, &ds, &cfg, 4096).unwrap();
        let (sh, _, _) =
            generate(&m, Param::Edm, &grid, &SolverSpec::Heun, &ds, &cfg, 4096).unwrap();
        let (fe, fh) = (fd_of(&se, &ds), fd_of(&sh, &ds));
        assert!(fh < fe, "heun {fh} should beat euler {fe}");
    }

    #[test]
    fn adaptive_step_saves_nfe_vs_heun() {
        let (m, ds, grid) = setup();
        let solver = SolverSpec::Adaptive {
            lambda: LambdaKind::Step,
            tau_k: 2e-4,
            clock: crate::diffusion::CurvatureClock::Sigma,
        };
        let cfg = RunConfig { rows: 64, seed: 4, ..Default::default() };
        let out = run_sampler(&m, Param::Edm, &grid, &solver, &ds, &cfg).unwrap();
        let heun_nfe = 2 * grid.intervals() - 1;
        assert!(out.nfe < heun_nfe, "adaptive {} vs heun {heun_nfe}", out.nfe);
        assert!(out.nfe > grid.intervals(), "should use some heun steps");
    }

    #[test]
    fn adaptive_quality_close_to_heun() {
        let (m, ds, grid) = setup();
        let solver = SolverSpec::Adaptive {
            lambda: LambdaKind::Step,
            tau_k: 2e-4,
            clock: crate::diffusion::CurvatureClock::Sigma,
        };
        let cfg = RunConfig { rows: 256, seed: 5, ..Default::default() };
        let (sa, _, _) = generate(&m, Param::Edm, &grid, &solver, &ds, &cfg, 4096).unwrap();
        let (sh, _, _) =
            generate(&m, Param::Edm, &grid, &SolverSpec::Heun, &ds, &cfg, 4096).unwrap();
        let (fa, fh) = (fd_of(&sa, &ds), fd_of(&sh, &ds));
        assert!(fa < fh * 2.0 + 0.05, "adaptive {fa} vs heun {fh}");
    }

    #[test]
    fn all_parameterizations_produce_finite_samples() {
        let (m, ds, grid) = setup();
        for p in [Param::Edm, Param::vp(), Param::Ve] {
            let cfg = RunConfig { rows: 32, seed: 6, ..Default::default() };
            let out = run_sampler(&m, p, &grid, &SolverSpec::Heun, &ds, &cfg).unwrap();
            assert!(
                out.samples.iter().all(|v| v.is_finite()),
                "{:?} produced non-finite samples",
                p.name()
            );
            let fd = fd_of(&out.samples, &ds);
            assert!(fd < 5.0, "{:?} fd={fd}", p.name());
        }
    }

    #[test]
    fn conditional_sampling_matches_class_moments() {
        let (m, ds, grid) = setup();
        let cfg = RunConfig { rows: 256, seed: 7, class: Some(0), ..Default::default() };
        let (s, _, _) =
            generate(&m, Param::Edm, &grid, &SolverSpec::Heun, &ds, &cfg, 4096).unwrap();
        let stats = crate::metrics::sample_mean_cov(&s, ds.dim);
        let (cm, cc) = m.class_moments(0);
        let fd = crate::metrics::frechet_to_reference(&stats, &cm, &cc).unwrap();
        assert!(fd < 0.5, "conditional fd {fd}");
    }

    #[test]
    fn dpm2m_runs_and_beats_euler() {
        let (m, ds, grid) = setup();
        let cfg = RunConfig { rows: 256, seed: 8, ..Default::default() };
        let (sd, nfe, _) =
            generate(&m, Param::Edm, &grid, &SolverSpec::Dpm2m, &ds, &cfg, 4096).unwrap();
        let (se, _, _) =
            generate(&m, Param::Edm, &grid, &SolverSpec::Euler, &ds, &cfg, 4096).unwrap();
        assert_eq!(nfe as usize, grid.intervals());
        let (fd_d, fd_e) = (fd_of(&sd, &ds), fd_of(&se, &ds));
        assert!(fd_d < fd_e, "dpm2m {fd_d} vs euler {fd_e}");
    }

    #[test]
    fn dpm2m_rejects_vp() {
        let (m, ds, grid) = setup();
        let cfg = RunConfig::default();
        assert!(run_sampler(&m, Param::vp(), &grid, &SolverSpec::Dpm2m, &ds, &cfg).is_err());
    }

    #[test]
    fn stochastic_requires_edm_param() {
        let (m, ds, grid) = setup();
        let solver = SolverSpec::StochasticHeun(crate::solvers::ChurnParams::imagenet());
        let cfg = RunConfig { rows: 16, seed: 9, ..Default::default() };
        assert!(run_sampler(&m, Param::Ve, &grid, &solver, &ds, &cfg).is_err());
        let out = run_sampler(&m, Param::Edm, &grid, &solver, &ds, &cfg).unwrap();
        assert!(out.samples.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn trace_records_curvature_and_eta() {
        let (m, ds, grid) = setup();
        let cfg = RunConfig { rows: 64, seed: 10, trace: true, ..Default::default() };
        let out = run_sampler(&m, Param::Edm, &grid, &SolverSpec::Heun, &ds, &cfg).unwrap();
        assert_eq!(out.steps.len(), grid.intervals());
        assert!(out.steps[0].kappa_hat.is_none());
        assert!(out.steps[1].kappa_hat.is_some());
        // all but the final interval have η̂ measurements under Heun
        for (i, s) in out.steps.iter().enumerate().take(out.steps.len() - 1) {
            assert!(s.eta_hat.is_some(), "step {i} missing eta");
            assert!(s.eta_hat.unwrap() >= 0.0);
        }
        // curvature rises toward sigma -> 0 (Figure 2 shape)
        let early = out.steps[2].kappa_hat.unwrap();
        let late = out.steps[out.steps.len() - 3].kappa_hat.unwrap();
        assert!(late > early, "late {late} vs early {early}");
    }

    #[test]
    fn generate_covers_requested_total_with_partial_batch() {
        let (m, ds, grid) = setup();
        let cfg = RunConfig { rows: 50, seed: 11, ..Default::default() };
        let (s, nfe, _) =
            generate(&m, Param::Edm, &grid, &SolverSpec::Euler, &ds, &cfg, 120).unwrap();
        assert_eq!(s.len(), 120 * ds.dim);
        assert!(nfe > 0.0);
    }

    #[test]
    fn generate_pooled_matches_generate_exactly() {
        let (m, ds, grid) = setup();
        let model: Arc<dyn Denoiser> = Arc::new(toy());
        let pool = ThreadPool::new(4);
        for (total, rows) in [(333usize, 50usize), (64, 64), (7, 64), (256, 32)] {
            let cfg = RunConfig { rows, seed: 11, ..Default::default() };
            let (s1, n1, t1) =
                generate(&m, Param::Edm, &grid, &SolverSpec::Heun, &ds, &cfg, total).unwrap();
            let (s2, n2, t2) = generate_pooled(
                &model,
                Param::Edm,
                &grid,
                &SolverSpec::Heun,
                &ds,
                &cfg,
                total,
                &pool,
            )
            .unwrap();
            assert_eq!(s1, s2, "samples diverge at total={total} rows={rows}");
            assert_eq!(n1, n2, "nfe diverges at total={total} rows={rows}");
            assert_eq!(t1.len(), t2.len());
        }
    }

    #[test]
    fn generate_pooled_traces_first_batch_only() {
        let (_, ds, grid) = setup();
        let model: Arc<dyn Denoiser> = Arc::new(toy());
        let pool = ThreadPool::new(2);
        let cfg = RunConfig { rows: 16, seed: 3, trace: true, ..Default::default() };
        let (s, _, trace) = generate_pooled(
            &model,
            Param::Edm,
            &grid,
            &SolverSpec::Heun,
            &ds,
            &cfg,
            48,
            &pool,
        )
        .unwrap();
        assert_eq!(s.len(), 48 * ds.dim);
        assert_eq!(trace.len(), grid.intervals());
    }

    #[test]
    fn generate_pooled_from_inside_a_pool_job_does_not_deadlock() {
        // a single-thread pool whose only worker runs the outer job: every
        // helper is stuck behind it, so only caller-help can finish
        let (_, ds, grid) = setup();
        let dim = ds.dim;
        let model: Arc<dyn Denoiser> = Arc::new(toy());
        let pool = Arc::new(ThreadPool::new(1));
        let p2 = Arc::clone(&pool);
        let (tx, rx) = std::sync::mpsc::channel();
        pool.execute(move || {
            let cfg = RunConfig { rows: 8, seed: 5, ..Default::default() };
            let out = generate_pooled(
                &model,
                Param::Edm,
                &grid,
                &SolverSpec::Euler,
                &ds,
                &cfg,
                40,
                &p2,
            );
            let _ = tx.send(out.map(|(s, _, _)| s.len()));
        });
        let got = rx
            .recv_timeout(std::time::Duration::from_secs(60))
            .expect("pooled generation deadlocked");
        assert_eq!(got.unwrap(), 40 * dim);
    }

    #[test]
    fn deterministic_given_seed() {
        let (m, ds, grid) = setup();
        let cfg = RunConfig { rows: 8, seed: 42, ..Default::default() };
        let a = run_sampler(&m, Param::Edm, &grid, &SolverSpec::Heun, &ds, &cfg).unwrap();
        let b = run_sampler(&m, Param::Edm, &grid, &SolverSpec::Heun, &ds, &cfg).unwrap();
        assert_eq!(a.samples, b.samples);
    }

    #[test]
    fn single_segment_plan_is_bit_identical_to_run_sampler() {
        let (m, ds, grid) = setup();
        for solver in [SolverSpec::Euler, SolverSpec::Heun, SolverSpec::Dpm2m] {
            let cfg = RunConfig { rows: 16, seed: 77, trace: true, ..Default::default() };
            let a = run_sampler(&m, Param::Edm, &grid, &solver, &ds, &cfg).unwrap();
            let b =
                run_plan(&m, Param::Edm, &grid, &SamplingPlan::single(solver), &ds, &cfg).unwrap();
            let ab: Vec<u32> = a.samples.iter().map(|v| v.to_bits()).collect();
            let bb: Vec<u32> = b.samples.iter().map(|v| v.to_bits()).collect();
            assert_eq!(ab, bb, "{solver:?}");
            assert_eq!(a.nfe, b.nfe);
            assert_eq!(a.steps.len(), b.steps.len());
        }
    }

    #[test]
    fn segmented_plan_attributes_nfe_per_segment() {
        let (m, ds, grid) = setup();
        // split at the middle knot so both segments are non-empty
        let mid = grid.sigmas[grid.intervals() / 2];
        let plan =
            SamplingPlan::parse(&format!("euler@max..{mid},heun@{mid}..0")).unwrap();
        let cfg = RunConfig { rows: 16, seed: 12, trace: true, ..Default::default() };
        let out = run_plan(&m, Param::Edm, &grid, &plan, &ds, &cfg).unwrap();
        let n0 = grid.intervals() / 2;
        let n1 = grid.intervals() - n0;
        // euler: 1 eval/interval; heun: 2 per interval except the σ→0 one
        assert_eq!(out.seg_nfe, vec![n0, 2 * n1 - 1]);
        assert_eq!(out.nfe, out.seg_nfe.iter().sum::<usize>());
        // trace records carry their segment index
        assert!(out.steps[..n0].iter().all(|s| s.segment == 0));
        assert!(out.steps[n0..].iter().all(|s| s.segment == 1));
        assert!(out.samples.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn segmented_plan_quality_between_its_endpoints() {
        let (m, ds, grid) = setup();
        let mid = grid.sigmas[grid.intervals() / 2];
        let plan =
            SamplingPlan::parse(&format!("euler@max..{mid},heun@{mid}..0")).unwrap();
        let cfg = RunConfig { rows: 256, seed: 13, ..Default::default() };
        let (ss, _, _, _) = generate_plan(&m, Param::Edm, &grid, &plan, &ds, &cfg, 4096).unwrap();
        let (se, _, _) =
            generate(&m, Param::Edm, &grid, &SolverSpec::Euler, &ds, &cfg, 4096).unwrap();
        let fd_seg = fd_of(&ss, &ds);
        let fd_e = fd_of(&se, &ds);
        assert!(
            fd_seg < fd_e,
            "heun tail should lift the segmented plan over pure euler: {fd_seg} vs {fd_e}"
        );
    }

    #[test]
    fn pid_arm_runs_on_all_parameterizations() {
        let (m, ds, grid) = setup();
        let plan = SamplingPlan::single(SolverSpec::Pid(PidParams::default()));
        for p in [Param::Edm, Param::vp(), Param::Ve] {
            let cfg = RunConfig { rows: 32, seed: 14, trace: true, ..Default::default() };
            let out = run_plan(&m, p, &grid, &plan, &ds, &cfg).unwrap();
            assert!(
                out.samples.iter().all(|v| v.is_finite()),
                "{:?} produced non-finite samples",
                p.name()
            );
            // 2 evals per accepted step + 1 closing euler step; accepted
            // steps are recorded in the trace
            assert!(out.nfe >= 3, "{:?} nfe {}", p.name(), out.nfe);
            assert_eq!(out.nfe, out.seg_nfe[0]);
            assert!(!out.steps.is_empty());
            let fd = fd_of(&out.samples, &ds);
            assert!(fd < 5.0, "{:?} pid fd={fd}", p.name());
        }
    }

    #[test]
    fn pid_arm_is_deterministic() {
        let (m, ds, grid) = setup();
        let plan = SamplingPlan::single(SolverSpec::Pid(PidParams::default()));
        let cfg = RunConfig { rows: 8, seed: 15, ..Default::default() };
        let a = run_plan(&m, Param::Edm, &grid, &plan, &ds, &cfg).unwrap();
        let b = run_plan(&m, Param::Edm, &grid, &plan, &ds, &cfg).unwrap();
        assert_eq!(a.samples, b.samples);
        assert_eq!(a.nfe, b.nfe);
    }

    #[test]
    fn pid_tail_segment_composes_with_fixed_head() {
        let (m, ds, grid) = setup();
        let mid = grid.sigmas[grid.intervals() / 2];
        let plan = SamplingPlan::parse(&format!("heun@max..{mid},pid@{mid}..0")).unwrap();
        let cfg = RunConfig { rows: 32, seed: 16, trace: true, ..Default::default() };
        let out = run_plan(&m, Param::Edm, &grid, &plan, &ds, &cfg).unwrap();
        assert!(out.samples.iter().all(|v| v.is_finite()));
        assert_eq!(out.seg_nfe.len(), 2);
        assert_eq!(out.nfe, out.seg_nfe.iter().sum::<usize>());
        assert!(out.seg_nfe[1] >= 1, "pid tail must at least close σ→0");
        let fd = fd_of(&out.samples, &ds);
        assert!(fd < 5.0, "composed plan fd={fd}");
    }

    #[test]
    fn cancel_token_aborts_mid_run_with_exact_accounting() {
        let (m, ds, grid) = setup();
        let token = CancelToken::new();
        let t2 = token.clone();
        // deterministic trip: the hook cancels after the third step, so
        // the engine must abort at the very next once-per-step check
        let hook: ProgressHook = Arc::new(move |p: StepProgress| {
            if p.step >= 3 {
                t2.cancel();
            }
        });
        let ctl = RunCtl { cancel: Some(token), progress: Some(hook), preview_dims: 2 };
        let cfg = RunConfig { rows: 8, seed: 21, ..Default::default() };
        let mask = mask_row_for(None, &ds, m.k()).unwrap();
        let plan = SamplingPlan::single(SolverSpec::Heun);
        let out = run_plan_masked_ctl(
            &m,
            Param::Edm,
            &grid,
            &plan,
            &cfg,
            &mask,
            KernelPrecision::Exact,
            &ctl,
        )
        .unwrap();
        let full = run_sampler(&m, Param::Edm, &grid, &SolverSpec::Heun, &ds, &cfg).unwrap();
        assert!(out.cancelled, "token tripped mid-run must mark the result cancelled");
        assert!(!full.cancelled && full.nfe_refunded == 0.0);
        assert_eq!(out.nfe, 6, "3 heun steps spend exactly 6 evals before the trip");
        assert!(out.nfe < full.nfe);
        assert_eq!(out.seg_nfe.iter().sum::<usize>(), out.nfe, "attribution stays exact");
        // spent + refund == the plan's full deterministic cost
        assert_eq!(
            out.nfe as f64 + out.nfe_refunded,
            plan_nfe_estimate(&plan, &grid.sigmas)
        );
        assert_eq!(plan_nfe_estimate(&plan, &grid.sigmas), full.nfe as f64);
        // partial state is still a full [rows, dim] buffer
        assert_eq!(out.samples.len(), 8 * ds.dim);
    }

    #[test]
    fn cancel_token_pre_tripped_refunds_the_whole_run() {
        let (m, ds, grid) = setup();
        let token = CancelToken::new();
        token.cancel();
        let ctl = RunCtl { cancel: Some(token), progress: None, preview_dims: 0 };
        let cfg = RunConfig { rows: 4, seed: 22, ..Default::default() };
        let mask = mask_row_for(None, &ds, m.k()).unwrap();
        let plan = SamplingPlan::single(SolverSpec::Euler);
        let out = run_plan_masked_ctl(
            &m,
            Param::Edm,
            &grid,
            &plan,
            &cfg,
            &mask,
            KernelPrecision::Exact,
            &ctl,
        )
        .unwrap();
        assert!(out.cancelled);
        assert_eq!(out.nfe, 0);
        assert_eq!(out.nfe_refunded, grid.intervals() as f64);
    }

    #[test]
    fn progress_hook_reports_monotone_trajectory() {
        let (m, ds, grid) = setup();
        let seen: Arc<Mutex<Vec<StepProgress>>> = Arc::new(Mutex::new(Vec::new()));
        let sink = Arc::clone(&seen);
        let hook: ProgressHook = Arc::new(move |p: StepProgress| {
            sink.lock().expect("test sink poisoned").push(p);
        });
        let ctl = RunCtl { cancel: None, progress: Some(hook), preview_dims: 2 };
        let cfg = RunConfig { rows: 4, seed: 23, ..Default::default() };
        let mask = mask_row_for(None, &ds, m.k()).unwrap();
        let plan = SamplingPlan::single(SolverSpec::Euler);
        let out = run_plan_masked_ctl(
            &m,
            Param::Edm,
            &grid,
            &plan,
            &cfg,
            &mask,
            KernelPrecision::Exact,
            &ctl,
        )
        .unwrap();
        assert!(!out.cancelled);
        let events = seen.lock().expect("test sink poisoned");
        assert_eq!(events.len(), grid.intervals(), "one event per completed step");
        for (i, e) in events.iter().enumerate() {
            assert_eq!(e.step, i + 1);
            assert_eq!(e.segment, 0);
            assert_eq!(e.preview.len(), 2);
            if i > 0 {
                assert!(e.sigma_remaining <= events[i - 1].sigma_remaining);
                assert!(e.nfe_spent >= events[i - 1].nfe_spent);
            }
        }
        assert_eq!(events.last().unwrap().sigma_remaining, 0.0, "trajectory must close");
        assert_eq!(events.last().unwrap().nfe_spent, out.nfe);
    }

    #[test]
    fn generate_ctl_propagates_cancellation_across_batches() {
        let (m, ds, grid) = setup();
        let token = CancelToken::new();
        let t2 = token.clone();
        // trip during the second batch: first batch completes untouched
        let n_int = grid.intervals();
        let hook: ProgressHook = Arc::new(move |p: StepProgress| {
            if p.step >= n_int {
                t2.cancel();
            }
        });
        let ctl = RunCtl { cancel: Some(token), progress: Some(hook), preview_dims: 0 };
        let cfg = RunConfig { rows: 4, seed: 24, ..Default::default() };
        let plan = SamplingPlan::single(SolverSpec::Euler);
        let (samples, _, _, _, refunded) = generate_plan_ctl(
            &m,
            Param::Edm,
            &grid,
            &plan,
            &ds,
            &cfg,
            12,
            KernelPrecision::Exact,
            &ctl,
        )
        .unwrap();
        let refunded = refunded.expect("run must report cancellation");
        // batch 1 finished (4 rows); batch 2 aborted at its first check but
        // still returns its prior-state rows; batch 3 never started
        assert!(samples.len() >= 4 * ds.dim && samples.len() <= 8 * ds.dim);
        // refund covers the aborted batch plus the never-started batch
        assert_eq!(refunded, 2.0 * grid.intervals() as f64);
    }

    #[test]
    fn generate_pooled_plan_matches_generate_plan_exactly() {
        let (m, ds, grid) = setup();
        let model: Arc<dyn Denoiser> = Arc::new(toy());
        let pool = ThreadPool::new(4);
        let mid = grid.sigmas[grid.intervals() / 2];
        let plan = SamplingPlan::parse(&format!("euler@max..{mid},heun@{mid}..0")).unwrap();
        let cfg = RunConfig { rows: 50, seed: 17, ..Default::default() };
        let (s1, n1, _, g1) = generate_plan(&m, Param::Edm, &grid, &plan, &ds, &cfg, 333).unwrap();
        let (s2, n2, _, g2) =
            generate_pooled_plan(&model, Param::Edm, &grid, &plan, &ds, &cfg, 333, &pool).unwrap();
        assert_eq!(s1, s2);
        assert_eq!(n1, n2);
        assert_eq!(g1, g2);
        assert_eq!(g1.len(), 2);
    }
}
