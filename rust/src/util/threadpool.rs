//! Fixed-size worker pool substrate (no tokio in the vendored crate set).
//!
//! The coordinator's batcher flushes, pooled row-sharded generation, and
//! the experiment grids all run on this pool (TCP connection handlers
//! stay on their own plain threads — see `coordinator::server`). Jobs are
//! boxed closures over an mpsc channel guarded by a mutex on the
//! receiving side; `map_indices` / `try_map_indices` provide the one
//! data-parallel primitive the experiments need.
//!
//! Panic policy: a panicking job must not poison the substrate. Workers
//! catch unwinds, so a panic neither kills the worker thread nor leaks
//! the `queued` gauge (the decrement is a drop guard); panics are counted
//! and surfaced by [`ThreadPool::panicked`], and `try_map_indices`
//! reports them as errors instead of hanging or aborting the caller.

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use crate::Result;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads executing boxed jobs.
///
/// The pool is shared across threads (`Arc<ThreadPool>` is how the
/// coordinator hands it to every batcher), so the submission side is
/// mutex-wrapped. (The router's route senders dropped their mutexes —
/// `mpsc::Sender` is `Sync` on modern std — but `execute` also guards the
/// `tx: Option<..>` shutdown state, so the lock stays; job submission is
/// not the coordinator's hot path.)
pub struct ThreadPool {
    tx: Option<Mutex<mpsc::Sender<Job>>>,
    workers: Vec<JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
    panicked: Arc<AtomicUsize>,
}

/// Decrements the in-flight gauge even when the job unwinds.
struct QueuedGuard<'a>(&'a AtomicUsize);

impl Drop for QueuedGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let panicked = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let queued = Arc::clone(&queued);
                let panicked = Arc::clone(&panicked);
                std::thread::Builder::new()
                    .name(format!("sdm-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().expect("pool receiver poisoned");
                            // lint: allow(lock): the receiver mutex exists only to serialize recv across workers; holding it over the blocking recv IS the design
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                let _dec = QueuedGuard(&queued);
                                // a panicking job is the job's bug, not the
                                // pool's: swallow the unwind, keep serving
                                if std::panic::catch_unwind(AssertUnwindSafe(job)).is_err() {
                                    panicked.fetch_add(1, Ordering::SeqCst);
                                }
                            }
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawning worker thread")
            })
            .collect();
        ThreadPool { tx: Some(Mutex::new(tx)), workers, queued, panicked }
    }

    /// Number of jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.queued.load(Ordering::SeqCst)
    }

    /// Number of jobs that panicked since the pool started.
    pub fn panicked(&self) -> usize {
        self.panicked.load(Ordering::SeqCst)
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Fire-and-forget job submission.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.queued.fetch_add(1, Ordering::SeqCst);
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .lock()
            .expect("pool sender poisoned")
            // lint: allow(lock): temporary guard; the sender mutex only serializes send on an unbounded channel, so send cannot block
            .send(Box::new(f))
            .expect("pool workers gone");
    }

    /// Run `f` over each index in `0..n`, blocking until all complete, and
    /// return results in order. Panics (with the index list) if any worker
    /// job panicked; use [`ThreadPool::try_map_indices`] to get an error
    /// instead.
    pub fn map_indices<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + 'static,
    {
        self.try_map_indices(n, f).expect("map_indices worker panicked")
    }

    /// Like [`ThreadPool::map_indices`], but worker panics surface as an
    /// `Err` naming the failed indices instead of a panic or a hang: a
    /// panicking job drops its result sender during unwind, so the
    /// collection loop always terminates and the gaps are reported.
    pub fn try_map_indices<T, F>(&self, n: usize, f: F) -> Result<Vec<T>>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel();
        for i in 0..n {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let out = f(i);
                // receiver alive for the whole collection loop below
                let _ = tx.send((i, out));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, v) in rx {
            slots[i] = Some(v);
        }
        let missing: Vec<usize> = slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.is_none().then_some(i))
            .collect();
        anyhow::ensure!(
            missing.is_empty(),
            "{} worker job(s) panicked (indices {:?})",
            missing.len(),
            missing
        );
        Ok(slots.into_iter().map(|s| s.unwrap()).collect())
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join workers
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_indices_ordered() {
        let pool = ThreadPool::new(3);
        let out = pool.map_indices(50, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn map_indices_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<usize> = pool.map_indices(0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn panicking_job_neither_kills_workers_nor_leaks_pending() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("job bug"));
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..20 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        while pool.pending() > 0 {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(counter.load(Ordering::SeqCst), 20);
        assert_eq!(pool.panicked(), 1);
        assert_eq!(pool.pending(), 0);
    }

    #[test]
    fn try_map_indices_surfaces_panics_as_errors() {
        let pool = ThreadPool::new(3);
        let res = pool.try_map_indices(8, |i| {
            if i == 3 {
                panic!("index 3 is cursed");
            }
            i
        });
        let err = format!("{:#}", res.err().expect("panic must surface"));
        assert!(err.contains("panicked"), "{err}");
        assert!(err.contains('3'), "{err}");
        // the pool is still fully usable afterwards
        assert_eq!(pool.map_indices(4, |i| i + 1), vec![1, 2, 3, 4]);
        assert_eq!(pool.panicked(), 1);
    }
}
