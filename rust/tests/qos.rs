//! QoS integration: admission control must reject exactly the overflow
//! (never hang, never buffer unboundedly), deadlines must shed expired
//! requests with structured replies, the DRR scheduler must divide flush
//! slots by weight across routes, priorities must reorder the backlog,
//! shutdown must unblock every queued client, and the closed-loop
//! loadgen must be deterministic under a seeded trace.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use sdm::coordinator::batcher::BatchPolicy;
use sdm::coordinator::hub::EngineHub;
use sdm::coordinator::loadgen::{closed_loop, RequestTemplate, TraceProfile};
use sdm::coordinator::metrics::ServerMetrics;
use sdm::coordinator::protocol::{Request, Response, SampleRequest};
use sdm::coordinator::qos::{Inbox, PushRejected, QosPolicy};
use sdm::coordinator::router::Router;
use sdm::coordinator::{Client, Rejection, Server, ServerConfig};
use sdm::model::gmm::testmodel::toy;
use sdm::model::{DatasetInfo, Denoiser, EvalOut};
use sdm::util::ThreadPool;

/// Wraps the toy oracle behind a gate: every eval blocks until
/// [`GateDenoiser::release`], and the row count of each eval is recorded
/// in arrival order (deduplicated per flush by the tests).
struct GateDenoiser {
    inner: sdm::model::GmmModel,
    open: Mutex<bool>,
    cv: Condvar,
    started: AtomicUsize,
    rows_seen: Mutex<Vec<usize>>,
    hold: Duration,
}

impl GateDenoiser {
    fn new() -> Arc<GateDenoiser> {
        GateDenoiser::with_hold(Duration::ZERO)
    }

    /// Gate pre-opened, but every eval sleeps `hold` — a uniformly slow
    /// model for fairness scenarios.
    fn slow(hold: Duration) -> Arc<GateDenoiser> {
        let g = GateDenoiser::with_hold(hold);
        g.release();
        g
    }

    fn with_hold(hold: Duration) -> Arc<GateDenoiser> {
        Arc::new(GateDenoiser {
            inner: toy(),
            open: Mutex::new(false),
            cv: Condvar::new(),
            started: AtomicUsize::new(0),
            rows_seen: Mutex::new(Vec::new()),
            hold,
        })
    }

    fn release(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }

    /// Block until at least `n` evals have *started* (i.e. a flush is
    /// provably stalled inside the model).
    fn wait_started(&self, n: usize) {
        let deadline = Instant::now() + Duration::from_secs(30);
        while self.started.load(Ordering::SeqCst) < n {
            assert!(Instant::now() < deadline, "no eval started in time");
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    /// Eval row-counts in arrival order, consecutive duplicates removed
    /// (one flush = `steps` evals of the same row count).
    fn flush_order(&self) -> Vec<usize> {
        let rows = self.rows_seen.lock().unwrap();
        let mut out: Vec<usize> = Vec::new();
        for &r in rows.iter() {
            if out.last() != Some(&r) {
                out.push(r);
            }
        }
        out
    }
}

impl Denoiser for GateDenoiser {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn k(&self) -> usize {
        self.inner.k()
    }

    fn backend(&self) -> &'static str {
        "gate"
    }

    fn denoise_v(
        &self,
        xhat: &[f32],
        sigma: &[f32],
        a: &[f32],
        b: &[f32],
        mask: &[f32],
    ) -> sdm::Result<EvalOut> {
        self.rows_seen.lock().unwrap().push(sigma.len());
        self.started.fetch_add(1, Ordering::SeqCst);
        let mut open = self.open.lock().unwrap();
        while !*open {
            open = self.cv.wait(open).unwrap();
        }
        drop(open);
        if !self.hold.is_zero() {
            std::thread::sleep(self.hold);
        }
        self.inner.denoise_v(xhat, sigma, a, b, mask)
    }
}

fn mk(dataset: &str, n: usize, steps: usize, extra: &str) -> SampleRequest {
    let line = format!(
        r#"{{"op":"sample","dataset":"{dataset}","n":{n},"solver":"euler","steps":{steps}{extra}}}"#
    );
    match Request::parse(&line).unwrap() {
        Request::Sample(s) => s,
        _ => unreachable!(),
    }
}

fn renamed_info(name: &str) -> DatasetInfo {
    let mut info = toy().info;
    info.name = name.to_string();
    info
}

/// Overload scenario (acceptance criterion): with inbox depth D and a
/// stalled model, exactly the overflow requests get `QueueFull` — no
/// hang, no unbounded buffering — and every accepted request is still
/// served once the model unblocks.
#[test]
fn overload_rejects_exactly_the_overflow() {
    let gate = GateDenoiser::new();
    let model: Arc<dyn Denoiser> = gate.clone();
    let hub = Arc::new(EngineHub::from_models(vec![(toy().info, model)]));
    let metrics = Arc::new(ServerMetrics::new());
    let policy = BatchPolicy {
        max_batch: 1, // every request its own chunk: nothing merges past the stall
        max_wait: Duration::from_millis(1),
        max_inflight: 1,
    };
    let depth = 4usize;
    let qos = QosPolicy { inbox_depth: depth, ..QosPolicy::default() };
    let router = Router::start_with_qos(
        hub,
        metrics.clone(),
        policy,
        qos,
        Arc::new(ThreadPool::new(2)),
    );

    // one request occupies the single in-flight flush and stalls
    let first = router.submit(mk("toy", 1, 4, "")).unwrap();
    gate.wait_started(1);
    // fill the remaining admission slots (outstanding: first + these)
    let accepted: Vec<_> = (0..depth - 1)
        .map(|_| router.submit(mk("toy", 1, 4, "")).unwrap())
        .collect();
    // the overflow: rejected at enqueue, immediately and structurally
    let overflow = 3usize;
    let rejected: Vec<_> = (0..overflow)
        .map(|_| router.submit(mk("toy", 1, 4, "")).unwrap())
        .collect();
    for rx in &rejected {
        match rx.recv_timeout(Duration::from_secs(10)).unwrap() {
            Response::QueueFull { depth: d, retry_after_ms, route } => {
                assert_eq!(d, depth, "rejection must report the outstanding bound");
                assert!(retry_after_ms > 0.0);
                assert_eq!(route, "toy");
            }
            other => panic!("overflow request got {other:?}, want QueueFull"),
        }
    }
    // no accepted request was harmed: unblock and collect all of them
    gate.release();
    let t = Duration::from_secs(30);
    match first.recv_timeout(t).unwrap() {
        Response::SampleOk { .. } => {}
        other => panic!("{other:?}"),
    }
    for rx in &accepted {
        match rx.recv_timeout(t).unwrap() {
            Response::SampleOk { .. } => {}
            other => panic!("accepted request got {other:?}"),
        }
    }
    let snap = metrics.snapshot();
    let toy_m = snap.get("toy").unwrap();
    assert_eq!(
        toy_m.get("sheds_queue_full").unwrap().as_f64().unwrap(),
        overflow as f64,
        "exactly the overflow is counted as shed"
    );
    assert_eq!(toy_m.get("requests").unwrap().as_f64().unwrap(), depth as f64);
    router.shutdown();
}

/// Deadline semantics: requests whose budget expires while they queue
/// behind a stalled flush are shed pre-flush with `DeadlineExceeded` —
/// counted, never integrated late, never silently dropped.
#[test]
fn expired_requests_are_shed_pre_flush() {
    let gate = GateDenoiser::new();
    let model: Arc<dyn Denoiser> = gate.clone();
    let hub = Arc::new(EngineHub::from_models(vec![(toy().info, model)]));
    let metrics = Arc::new(ServerMetrics::new());
    let policy = BatchPolicy {
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        max_inflight: 1,
    };
    let router = Router::start_with_qos(
        hub,
        metrics.clone(),
        policy,
        QosPolicy::default(),
        Arc::new(ThreadPool::new(2)),
    );

    let first = router.submit(mk("toy", 1, 4, "")).unwrap();
    gate.wait_started(1);
    // a separate group (different steps) with a 20 ms budget, stuck
    // behind the stalled flush
    let doomed: Vec<_> = (0..2)
        .map(|_| router.submit(mk("toy", 1, 6, r#","deadline_ms":20"#)).unwrap())
        .collect();
    // a no-deadline sibling in the same group must survive the shed
    let survivor = router.submit(mk("toy", 1, 6, "")).unwrap();
    std::thread::sleep(Duration::from_millis(60)); // budgets expire in queue
    gate.release();
    let t = Duration::from_secs(30);
    match first.recv_timeout(t).unwrap() {
        Response::SampleOk { .. } => {}
        other => panic!("{other:?}"),
    }
    for rx in &doomed {
        match rx.recv_timeout(t).unwrap() {
            Response::DeadlineExceeded { deadline_ms, waited_ms, route } => {
                assert_eq!(deadline_ms, 20.0);
                assert!(waited_ms >= 20.0, "waited {waited_ms} < deadline");
                assert_eq!(route, "toy");
            }
            other => panic!("expired request got {other:?}, want DeadlineExceeded"),
        }
    }
    match survivor.recv_timeout(t).unwrap() {
        Response::SampleOk { .. } => {}
        other => panic!("survivor got {other:?}"),
    }
    let snap = metrics.snapshot();
    assert_eq!(
        snap.get("toy").unwrap().get("sheds_deadline").unwrap().as_f64().unwrap(),
        2.0
    );
    router.shutdown();
}

/// Priority semantics: with the single flush slot stalled, an
/// interactive request submitted *after* a background request must flush
/// *before* it once the slot frees (heap order, not arrival order).
#[test]
fn interactive_requests_preempt_background_in_the_backlog() {
    let gate = GateDenoiser::new();
    let model: Arc<dyn Denoiser> = gate.clone();
    let hub = Arc::new(EngineHub::from_models(vec![(toy().info, model)]));
    let metrics = Arc::new(ServerMetrics::new());
    let policy = BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_millis(1),
        max_inflight: 1,
    };
    let router = Router::start_with_qos(
        hub,
        metrics,
        policy,
        QosPolicy::default(),
        Arc::new(ThreadPool::new(2)),
    );

    // n=1: the stalled plug; n=2: background, arrives first; n=3:
    // interactive, arrives second — distinct row counts identify the
    // flush order inside the model
    let plug = router.submit(mk("toy", 1, 4, "")).unwrap();
    gate.wait_started(1);
    let background = router
        .submit(mk("toy", 2, 4, r#","priority":"background""#))
        .unwrap();
    std::thread::sleep(Duration::from_millis(10)); // both chunks reach the backlog
    let interactive = router
        .submit(mk("toy", 3, 4, r#","priority":"interactive""#))
        .unwrap();
    std::thread::sleep(Duration::from_millis(10));
    gate.release();
    let t = Duration::from_secs(30);
    for rx in [&plug, &background, &interactive] {
        match rx.recv_timeout(t).unwrap() {
            Response::SampleOk { .. } => {}
            other => panic!("{other:?}"),
        }
    }
    assert_eq!(
        gate.flush_order(),
        vec![1, 3, 2],
        "interactive (3 rows) must flush before the earlier background (2 rows)"
    );
    router.shutdown();
}

/// Cross-dataset fairness (acceptance criterion): under a mixed 2-route
/// load on one flush slot, DRR keeps each route's served share within 2x
/// of its configured weight while both routes have work queued.
#[test]
fn drr_divides_flush_slots_by_weight_across_routes() {
    let a_model: Arc<dyn Denoiser> = GateDenoiser::slow(Duration::from_millis(2));
    let b_model: Arc<dyn Denoiser> = GateDenoiser::slow(Duration::from_millis(2));
    let hub = Arc::new(EngineHub::from_models(vec![
        (renamed_info("alpha"), a_model),
        (renamed_info("bravo"), b_model),
    ]));
    let metrics = Arc::new(ServerMetrics::new());
    let policy = BatchPolicy {
        max_batch: 1, // one row per chunk: served_rows is a chunk counter
        max_wait: Duration::from_millis(1),
        max_inflight: 8,
    };
    let qos = QosPolicy {
        inbox_depth: 0, // unbounded: this test is about fairness, not admission
        flush_slots: 1, // serialize: DRR alone decides the order
        weights: QosPolicy::parse_weights("alpha=1,bravo=3").unwrap(),
        ..QosPolicy::default()
    };
    let router = Router::start_with_qos(
        hub,
        metrics,
        policy,
        qos,
        Arc::new(ThreadPool::new(2)),
    );

    let per_route = 32usize;
    let mut replies = Vec::new();
    for i in 0..per_route {
        for ds in ["alpha", "bravo"] {
            let mut r = mk(ds, 1, 2, "");
            r.seed = i as u64;
            replies.push(router.submit(r).unwrap());
        }
    }
    // snapshot served shares while both routes still have a backlog
    // (after the full drain both trivially converge to 32:32)
    let deadline = Instant::now() + Duration::from_secs(60);
    let (a_rows, b_rows) = loop {
        let served = router.scheduler().served_rows();
        let a = served.get("alpha").copied().unwrap_or(0);
        let b = served.get("bravo").copied().unwrap_or(0);
        if a + b >= 16 {
            break (a as f64, b as f64);
        }
        assert!(Instant::now() < deadline, "fairness scenario made no progress");
        std::thread::sleep(Duration::from_micros(200));
    };
    let total = a_rows + b_rows;
    let a_share = a_rows / total;
    let b_share = b_rows / total;
    // weights 1:3 -> fair shares 0.25 / 0.75; "within 2x" bounds
    assert!(
        (0.125..=0.5).contains(&a_share),
        "alpha share {a_share:.3} outside 2x of its 0.25 weight share (a={a_rows}, b={b_rows})"
    );
    assert!(
        b_share >= 0.375,
        "bravo share {b_share:.3} outside 2x of its 0.75 weight share (a={a_rows}, b={b_rows})"
    );
    for rx in replies {
        match rx.recv_timeout(Duration::from_secs(60)).unwrap() {
            Response::SampleOk { .. } => {}
            other => panic!("{other:?}"),
        }
    }
    router.shutdown();
}

/// Shutdown must unblock every client: accepted requests are served (or
/// shed with an explicit reply), and a post-shutdown submit fails fast —
/// nobody ever hangs on a dead socket.
#[test]
fn shutdown_never_strands_queued_clients() {
    let gate = GateDenoiser::new();
    let model: Arc<dyn Denoiser> = gate.clone();
    let hub = Arc::new(EngineHub::from_models(vec![(toy().info, model)]));
    let metrics = Arc::new(ServerMetrics::new());
    let policy = BatchPolicy {
        max_batch: 1,
        max_wait: Duration::from_millis(1),
        max_inflight: 1,
    };
    let router = Arc::new(Router::start_with_qos(
        hub,
        metrics,
        policy,
        QosPolicy::default(),
        Arc::new(ThreadPool::new(2)),
    ));

    let stalled = router.submit(mk("toy", 1, 4, "")).unwrap();
    gate.wait_started(1);
    let queued = router.submit(mk("toy", 1, 6, "")).unwrap();

    let r2 = router.clone();
    let release_gate = gate.clone();
    let released = Arc::new(AtomicBool::new(false));
    let released2 = released.clone();
    // release the model shortly after shutdown starts draining
    let releaser = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(30));
        released2.store(true, Ordering::SeqCst);
        release_gate.release();
    });
    r2.shutdown();
    assert!(
        released.load(Ordering::SeqCst),
        "shutdown returned before the stalled flush could finish: it cannot have drained"
    );
    let t = Duration::from_secs(10);
    match stalled.recv_timeout(t).unwrap() {
        Response::SampleOk { .. } => {}
        other => panic!("stalled request got {other:?}"),
    }
    // the queued request was accepted pre-shutdown: drain serves it
    match queued.recv_timeout(t).unwrap() {
        Response::SampleOk { .. } => {}
        other => panic!("queued request got {other:?}"),
    }
    releaser.join().unwrap();
    // post-shutdown submissions fail fast
    assert!(router.submit(mk("toy", 1, 4, "")).is_err());
}

/// The admission bound follows the request's whole lifetime: popping a
/// request from the inbox does NOT free its slot — only dropping it
/// (reply sent) does. Closed inboxes refuse pushes with a typed reason.
#[test]
fn inbox_bound_tracks_outstanding_not_queue_length() {
    let inbox = Inbox::new(2);
    let submit = |inbox: &Inbox| {
        let (rtx, rrx) = std::sync::mpsc::channel();
        let p = sdm::coordinator::batcher::Pending::new(mk("toy", 1, 4, ""), rtx);
        (inbox.try_push(p), rrx)
    };
    let (r1, _keep1) = submit(&inbox);
    assert!(r1.is_ok());
    let (r2, _keep2) = submit(&inbox);
    assert!(r2.is_ok());
    assert_eq!(inbox.outstanding(), 2);
    let (r3, _k3) = submit(&inbox);
    match r3 {
        Err(PushRejected::Full { outstanding, depth, .. }) => {
            assert_eq!((outstanding, depth), (2, 2));
        }
        _ => panic!("third push must reject Full"),
    }
    // popping into the batcher does not free the slot...
    let popped = inbox.try_recv().expect("queued request");
    assert_eq!(inbox.queued(), 1);
    assert_eq!(inbox.outstanding(), 2, "outstanding covers popped requests");
    let (r4, _k4) = submit(&inbox);
    assert!(matches!(r4, Err(PushRejected::Full { .. })));
    // ...dropping the request (reply sent) does
    drop(popped);
    assert_eq!(inbox.outstanding(), 1);
    let (r5, _keep5) = submit(&inbox);
    assert!(r5.is_ok());
    assert_eq!(inbox.outstanding_hwm(), 2);
    // closed inboxes refuse with a typed reason but keep handing out
    // accepted work
    inbox.close();
    let (r6, _k6) = submit(&inbox);
    assert!(matches!(r6, Err(PushRejected::Closed { .. })));
    assert!(inbox.try_recv().is_some());
    assert!(inbox.try_recv().is_some());
    assert!(inbox.try_recv().is_none());
    assert!(matches!(
        inbox.recv_timeout(Duration::from_millis(1)),
        Err(sdm::coordinator::qos::RecvError::Closed)
    ));
}

/// End-to-end typed rejection: over TCP, an admission-bound overflow
/// comes back through `Client::send_checked` as a typed `Err` the caller
/// can downcast and branch on — the full wire → code-field → `Rejection`
/// path, not just the in-process pieces.
#[test]
fn client_surfaces_queue_full_as_a_typed_error() {
    let gate = GateDenoiser::new();
    let model: Arc<dyn Denoiser> = gate.clone();
    let hub = Arc::new(EngineHub::from_models(vec![(toy().info, model)]));
    let mut cfg = ServerConfig::default();
    cfg.qos.inbox_depth = 1;
    cfg.policy.max_wait = Duration::from_millis(1);
    let server = Server::start(hub, cfg).unwrap();
    let addr = server.local_addr.to_string();

    // occupy the single admission slot with a request stalled in the model
    let line = r#"{"op":"sample","dataset":"toy","n":1,"solver":"euler","steps":4}"#;
    let a = addr.clone();
    let occupant = std::thread::spawn(move || {
        let mut c = Client::connect(&a).unwrap();
        c.send_checked(line)
    });
    gate.wait_started(1);
    // the slot is held: a second client's request must reject, typed
    let mut c = Client::connect(&addr).unwrap();
    let err = c.send_checked(line).expect_err("admission bound must reject");
    match err.downcast_ref::<Rejection>() {
        Some(Rejection::QueueFull { route, retry_after_ms, .. }) => {
            assert_eq!(route, "toy");
            assert!(*retry_after_ms > 0.0);
        }
        other => panic!("want a QueueFull rejection, got {other:?} ({err:#})"),
    }
    gate.release();
    let occupied = occupant.join().unwrap().expect("occupant must be served");
    assert_eq!(occupied.get("ok").unwrap(), &sdm::util::Json::Bool(true));
    assert_eq!(occupied.get("n").unwrap().as_f64().unwrap(), 1.0);
    server.shutdown();
}

/// Closed-loop loadgen determinism (satellite): the same seed draws the
/// same request trace — provable via the trace hash — and a different
/// seed draws a different one.
#[test]
fn closed_loop_loadgen_is_deterministic_given_a_seed() {
    let hub = Arc::new(EngineHub::from_infos(vec![toy().info]));
    let server = Server::start(hub, ServerConfig::default()).unwrap();
    let addr = server.local_addr.to_string();
    let tpl = |steps: usize| RequestTemplate {
        dataset: "toy".into(),
        n: 2,
        param: "edm".into(),
        solver: "euler".into(),
        plan: None,
        schedule: "edm".into(),
        steps,
        priority: None,
        deadline_ms: None,
        kernel_precision: None,
        request_id: None,
    };
    // two templates so the drawn sequence actually varies with the seed
    let profile =
        TraceProfile { templates: vec![(0.5, tpl(5)), (0.5, tpl(9))], chaos: None, burst: None };
    let run = |seed: u64| {
        closed_loop(&addr, &profile, 2, 16, Duration::ZERO, seed).unwrap()
    };
    let a = run(7);
    let b = run(7);
    let c = run(8);
    assert_eq!(a.sent, 32);
    assert_eq!(a.errors + a.sheds + a.expiries, 0, "toy traffic must all succeed");
    assert_eq!(a.trace_hash, b.trace_hash, "same seed must draw the same trace");
    assert_eq!(a.sent, b.sent);
    assert_ne!(a.trace_hash, c.trace_hash, "different seed must draw a different trace");
    server.shutdown();
}
