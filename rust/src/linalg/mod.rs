//! Dense linear-algebra substrate for the metric suite.
//!
//! The Fréchet distance (the paper's FID, see DESIGN.md §2) needs a matrix
//! square root of `C1^{1/2} C2 C1^{1/2}`; with workload dimensions ≤ 64 a
//! cyclic Jacobi eigensolver is simple, robust, and fast enough that the
//! metric never shows up in profiles. No external BLAS in the vendored
//! crate set, so everything is written out.

pub mod eigen;

use anyhow::{bail, Result};

/// Row-major square matrix of f64.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub n: usize,
    pub a: Vec<f64>,
}

impl Mat {
    pub fn zeros(n: usize) -> Mat {
        Mat { n, a: vec![0.0; n * n] }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Result<Mat> {
        let n = rows.len();
        for r in rows {
            if r.len() != n {
                bail!("matrix not square: {} vs {}", r.len(), n);
            }
        }
        Ok(Mat { n, a: rows.iter().flatten().copied().collect() })
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.n + j]
    }

    pub fn trace(&self) -> f64 {
        (0..self.n).map(|i| self.at(i, i)).sum()
    }

    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.n, other.n);
        let n = self.n;
        let mut out = Mat::zeros(n);
        for i in 0..n {
            for k in 0..n {
                let aik = self.at(i, k);
                if aik == 0.0 {
                    continue;
                }
                for j in 0..n {
                    out.a[i * n + j] += aik * other.at(k, j);
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Mat {
        let n = self.n;
        let mut out = Mat::zeros(n);
        for i in 0..n {
            for j in 0..n {
                out[(i, j)] = self.at(j, i);
            }
        }
        out
    }

    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!(self.n, other.n);
        Mat {
            n: self.n,
            a: self.a.iter().zip(&other.a).map(|(x, y)| x + y).collect(),
        }
    }

    pub fn scale(&self, c: f64) -> Mat {
        Mat { n: self.n, a: self.a.iter().map(|x| x * c).collect() }
    }

    /// Symmetrize: (A + A^T)/2 — used to scrub numeric asymmetry before
    /// feeding the Jacobi solver.
    pub fn symmetrized(&self) -> Mat {
        let n = self.n;
        let mut out = Mat::zeros(n);
        for i in 0..n {
            for j in 0..n {
                out[(i, j)] = 0.5 * (self.at(i, j) + self.at(j, i));
            }
        }
        out
    }

    /// Max absolute off-diagonal entry (convergence measure).
    pub fn max_offdiag(&self) -> f64 {
        let mut m = 0.0f64;
        for i in 0..self.n {
            for j in 0..self.n {
                if i != j {
                    m = m.max(self.at(i, j).abs());
                }
            }
        }
        m
    }

    /// Frobenius norm of (self - other).
    pub fn dist(&self, other: &Mat) -> f64 {
        self.a
            .iter()
            .zip(&other.a)
            .map(|(x, y)| (x - y) * (x - y))
            .sum::<f64>()
            .sqrt()
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.a[i * self.n + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        let n = self.n;
        &mut self.a[i * n + j]
    }
}

/// Symmetric PSD matrix square root via Jacobi eigendecomposition.
/// Negative eigenvalues (numeric noise around 0) are clamped.
pub fn sqrtm_psd(m: &Mat) -> Result<Mat> {
    let (vals, vecs) = eigen::jacobi_eigen(&m.symmetrized())?;
    let n = m.n;
    // V diag(sqrt(max(l,0))) V^T
    let mut out = Mat::zeros(n);
    for k in 0..n {
        let s = vals[k].max(0.0).sqrt();
        if s == 0.0 {
            continue;
        }
        for i in 0..n {
            let vik = vecs.at(i, k) * s;
            if vik == 0.0 {
                continue;
            }
            for j in 0..n {
                out.a[i * n + j] += vik * vecs.at(j, k);
            }
        }
    }
    Ok(out)
}

/// trace of sqrtm(C1 C2) computed via the symmetric PSD reformulation
/// tr sqrtm(C1^{1/2} C2 C1^{1/2}) — the quantity FID needs.
pub fn trace_sqrt_product(c1: &Mat, c2: &Mat) -> Result<f64> {
    let s1 = sqrtm_psd(c1)?;
    let inner = s1.matmul(c2).matmul(&s1);
    let (vals, _) = eigen::jacobi_eigen(&inner.symmetrized())?;
    Ok(vals.iter().map(|l| l.max(0.0).sqrt()).sum())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_psd(n: usize, seed: u64) -> Mat {
        let mut rng = crate::util::Rng::new(seed);
        let mut b = Mat::zeros(n);
        for i in 0..n {
            for j in 0..n {
                b[(i, j)] = rng.normal();
            }
        }
        // B B^T + eps I is PSD
        let mut m = b.matmul(&b.transpose());
        for i in 0..n {
            m[(i, i)] += 1e-6;
        }
        m
    }

    #[test]
    fn matmul_identity() {
        let m = rand_psd(5, 1);
        let i = Mat::eye(5);
        assert!(m.matmul(&i).dist(&m) < 1e-12);
        assert!(i.matmul(&m).dist(&m) < 1e-12);
    }

    #[test]
    fn sqrtm_squares_back() {
        for n in [1, 2, 3, 8, 16] {
            let m = rand_psd(n, 42 + n as u64);
            let s = sqrtm_psd(&m).unwrap();
            let back = s.matmul(&s);
            assert!(
                back.dist(&m) < 1e-8 * (1.0 + m.trace().abs()),
                "n={n} err={}",
                back.dist(&m)
            );
        }
    }

    #[test]
    fn sqrtm_of_diagonal() {
        let mut m = Mat::zeros(3);
        m[(0, 0)] = 4.0;
        m[(1, 1)] = 9.0;
        m[(2, 2)] = 16.0;
        let s = sqrtm_psd(&m).unwrap();
        assert!((s.at(0, 0) - 2.0).abs() < 1e-10);
        assert!((s.at(1, 1) - 3.0).abs() < 1e-10);
        assert!((s.at(2, 2) - 4.0).abs() < 1e-10);
        assert!(s.max_offdiag() < 1e-10);
    }

    #[test]
    fn trace_sqrt_product_commuting_case() {
        // For C1 = C2 = C: tr sqrtm(C^2) = tr C
        let c = rand_psd(6, 5);
        let t = trace_sqrt_product(&c, &c).unwrap();
        assert!((t - c.trace()).abs() < 1e-7 * c.trace());
    }

    #[test]
    fn trace_sqrt_product_identity_scaling() {
        // C1 = a I, C2 = b I -> tr sqrtm(ab I) = n sqrt(ab)
        let n = 4;
        let c1 = Mat::eye(n).scale(4.0);
        let c2 = Mat::eye(n).scale(9.0);
        let t = trace_sqrt_product(&c1, &c2).unwrap();
        assert!((t - (n as f64) * 6.0).abs() < 1e-9);
    }

    #[test]
    fn from_rows_rejects_ragged() {
        assert!(Mat::from_rows(&[vec![1.0, 2.0], vec![3.0]]).is_err());
    }
}
