//! Client-side resilience primitives: budget-capped retry backoff with
//! decorrelated jitter, and a per-route circuit breaker (DESIGN.md §12).
//!
//! [`Backoff`] implements the decorrelated-jitter schedule
//! (`sleep = min(cap, uniform(base, prev * 3))`, floored by the server's
//! `retry_after_ms` hint when one was returned) under two hard limits: a
//! maximum attempt count and a total sleep budget. Jitter draws come from
//! the caller's seeded [`Rng`], so a fixed-seed load run retries at
//! reproducible instants.
//!
//! [`CircuitBreaker`] is the classic three-state machine: `Closed` counts
//! consecutive failures and trips to `Open` at the configured threshold;
//! `Open` fast-fails every acquire until the cooldown elapses, then lets
//! exactly one probe through as `HalfOpen`; the probe's outcome either
//! re-closes the breaker or re-opens it for another cooldown. A downed
//! route therefore sheds load locally instead of burning backoff budget
//! against a dead socket.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::{lock_unpoisoned, Rng};

/// Retry policy knobs (`--retry-*` CLI flags).
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// total attempts including the first (1 = never retry).
    pub max_attempts: usize,
    /// first backoff draw's lower bound, ms.
    pub base_ms: f64,
    /// upper bound of any single backoff sleep, ms.
    pub cap_ms: f64,
    /// total sleep budget across all retries of one request, ms.
    pub budget_ms: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_attempts: 4, base_ms: 5.0, cap_ms: 250.0, budget_ms: 1_000.0 }
    }
}

/// One request's retry state: attempt counter, jitter stream, and spent
/// sleep budget. Create a fresh one per logical request.
pub struct Backoff {
    policy: RetryPolicy,
    rng: Rng,
    prev_ms: f64,
    slept_ms: f64,
    attempts: usize,
}

impl Backoff {
    pub fn new(policy: RetryPolicy, rng: Rng) -> Backoff {
        Backoff { policy, rng, prev_ms: policy.base_ms, slept_ms: 0.0, attempts: 1 }
    }

    /// The delay to sleep before the next retry, or `None` when the
    /// attempt count or sleep budget is exhausted (the caller should
    /// surface the last outcome as terminal). `hint_ms` — the server's
    /// `retry_after_ms` backpressure hint — floors the jittered draw, so
    /// a client never retries earlier than the server asked.
    pub fn next_delay(&mut self, hint_ms: Option<f64>) -> Option<Duration> {
        if self.attempts >= self.policy.max_attempts {
            return None;
        }
        let hi = (self.prev_ms * 3.0).max(self.policy.base_ms * (1.0 + 1e-9));
        let mut ms = self.rng.uniform_range(self.policy.base_ms, hi).min(self.policy.cap_ms);
        if let Some(h) = hint_ms {
            ms = ms.max(h.max(0.0));
        }
        if self.slept_ms + ms > self.policy.budget_ms {
            return None;
        }
        self.attempts += 1;
        self.prev_ms = ms;
        self.slept_ms += ms;
        Some(Duration::from_secs_f64(ms / 1e3))
    }

    /// Attempts begun so far (1 before any retry).
    pub fn attempts(&self) -> usize {
        self.attempts
    }

    /// Total backoff sleep scheduled so far, ms.
    pub fn slept_ms(&self) -> f64 {
        self.slept_ms
    }
}

/// Circuit breaker policy knobs (`--breaker-*` CLI flags).
#[derive(Clone, Copy, Debug)]
pub struct BreakerConfig {
    /// consecutive failures that trip `Closed` → `Open`.
    pub threshold: usize,
    /// how long `Open` fast-fails before allowing a half-open probe.
    pub cooldown: Duration,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        BreakerConfig { threshold: 5, cooldown: Duration::from_millis(250) }
    }
}

#[derive(Clone, Copy, Debug)]
enum BreakerState {
    Closed { fails: usize },
    Open { until: Instant },
    HalfOpen,
}

/// Per-route circuit breaker. All transitions are made under one short
/// lock; the breaker never sleeps or does I/O, so it is safe on the
/// request hot path.
pub struct CircuitBreaker {
    cfg: BreakerConfig,
    // lock-order: 15
    state: Mutex<BreakerState>,
    opened: AtomicU64,
    reclosed: AtomicU64,
    fast_fails: AtomicU64,
}

impl CircuitBreaker {
    pub fn new(cfg: BreakerConfig) -> CircuitBreaker {
        CircuitBreaker {
            cfg,
            state: Mutex::new(BreakerState::Closed { fails: 0 }),
            opened: AtomicU64::new(0),
            reclosed: AtomicU64::new(0),
            fast_fails: AtomicU64::new(0),
        }
    }

    /// May a request be sent now? `false` = fast-fail locally without
    /// touching the network. An elapsed cooldown converts `Open` into a
    /// single `HalfOpen` probe admission; while that probe is in flight,
    /// further acquires keep fast-failing.
    pub fn try_acquire(&self) -> bool {
        let mut st = lock_unpoisoned(&self.state);
        match *st {
            BreakerState::Closed { .. } => true,
            BreakerState::HalfOpen => {
                self.fast_fails.fetch_add(1, Ordering::Relaxed);
                false
            }
            BreakerState::Open { until } => {
                if Instant::now() >= until {
                    *st = BreakerState::HalfOpen;
                    true
                } else {
                    self.fast_fails.fetch_add(1, Ordering::Relaxed);
                    false
                }
            }
        }
    }

    /// Record a successful attempt: resets the failure streak, and closes
    /// the breaker if this was the half-open probe.
    pub fn on_success(&self) {
        let mut st = lock_unpoisoned(&self.state);
        if matches!(*st, BreakerState::HalfOpen | BreakerState::Open { .. }) {
            self.reclosed.fetch_add(1, Ordering::Relaxed);
        }
        *st = BreakerState::Closed { fails: 0 };
    }

    /// Record a failed attempt: extends the streak, trips the breaker at
    /// the threshold, and re-opens it after a failed half-open probe.
    pub fn on_failure(&self) {
        let mut st = lock_unpoisoned(&self.state);
        match *st {
            BreakerState::Closed { fails } => {
                let fails = fails + 1;
                if fails >= self.cfg.threshold.max(1) {
                    *st = BreakerState::Open { until: Instant::now() + self.cfg.cooldown };
                    self.opened.fetch_add(1, Ordering::Relaxed);
                } else {
                    *st = BreakerState::Closed { fails };
                }
            }
            BreakerState::HalfOpen => {
                *st = BreakerState::Open { until: Instant::now() + self.cfg.cooldown };
                self.opened.fetch_add(1, Ordering::Relaxed);
            }
            BreakerState::Open { .. } => {}
        }
    }

    /// Current state as a metrics label.
    pub fn state_name(&self) -> &'static str {
        match *lock_unpoisoned(&self.state) {
            BreakerState::Closed { .. } => "closed",
            BreakerState::Open { .. } => "open",
            BreakerState::HalfOpen => "half_open",
        }
    }

    /// Times the breaker tripped to `Open`.
    pub fn opened(&self) -> u64 {
        self.opened.load(Ordering::Relaxed)
    }

    /// Times a half-open probe succeeded and re-closed the breaker.
    pub fn reclosed(&self) -> u64 {
        self.reclosed.load(Ordering::Relaxed)
    }

    /// Requests fast-failed locally while open/half-open.
    pub fn fast_fails(&self) -> u64 {
        self.fast_fails.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_is_bounded_and_deterministic() {
        let policy = RetryPolicy { max_attempts: 8, base_ms: 2.0, cap_ms: 20.0, budget_ms: 1e6 };
        let mut a = Backoff::new(policy, Rng::new(5));
        let mut b = Backoff::new(policy, Rng::new(5));
        let da: Vec<Duration> = std::iter::from_fn(|| a.next_delay(None)).collect();
        let db: Vec<Duration> = std::iter::from_fn(|| b.next_delay(None)).collect();
        assert_eq!(da, db, "same seed must schedule identical retries");
        assert_eq!(da.len(), 7, "max_attempts 8 = 7 retries");
        for d in &da {
            assert!(*d >= Duration::from_secs_f64(2.0 / 1e3));
            assert!(*d <= Duration::from_secs_f64(20.0 / 1e3));
        }
    }

    #[test]
    fn backoff_honors_server_hint_as_floor() {
        let policy = RetryPolicy { max_attempts: 4, base_ms: 1.0, cap_ms: 10.0, budget_ms: 1e6 };
        let mut b = Backoff::new(policy, Rng::new(1));
        let d = b.next_delay(Some(50.0)).unwrap();
        assert!(d >= Duration::from_millis(50), "hint must floor the draw, got {d:?}");
    }

    #[test]
    fn backoff_budget_exhausts() {
        let policy =
            RetryPolicy { max_attempts: 100, base_ms: 4.0, cap_ms: 10.0, budget_ms: 12.0 };
        let mut b = Backoff::new(policy, Rng::new(2));
        let n = std::iter::from_fn(|| b.next_delay(None)).count();
        assert!(n <= 3, "12ms budget cannot fund {n} sleeps of >= 4ms");
        assert!(b.slept_ms() <= 12.0);
    }

    #[test]
    fn breaker_walks_closed_open_halfopen_closed() {
        let cfg = BreakerConfig { threshold: 3, cooldown: Duration::from_millis(20) };
        let br = CircuitBreaker::new(cfg);
        assert_eq!(br.state_name(), "closed");
        for _ in 0..2 {
            assert!(br.try_acquire());
            br.on_failure();
        }
        assert_eq!(br.state_name(), "closed", "below threshold stays closed");
        assert!(br.try_acquire());
        br.on_failure();
        assert_eq!(br.state_name(), "open");
        assert_eq!(br.opened(), 1);
        assert!(!br.try_acquire(), "open must fast-fail");
        assert!(br.fast_fails() >= 1);
        std::thread::sleep(Duration::from_millis(25));
        assert!(br.try_acquire(), "cooldown elapsed: one probe admitted");
        assert_eq!(br.state_name(), "half_open");
        assert!(!br.try_acquire(), "only one half-open probe at a time");
        br.on_success();
        assert_eq!(br.state_name(), "closed");
        assert_eq!(br.reclosed(), 1);
    }

    #[test]
    fn failed_probe_reopens() {
        let cfg = BreakerConfig { threshold: 1, cooldown: Duration::from_millis(10) };
        let br = CircuitBreaker::new(cfg);
        br.on_failure();
        assert_eq!(br.state_name(), "open");
        std::thread::sleep(Duration::from_millis(15));
        assert!(br.try_acquire());
        br.on_failure();
        assert_eq!(br.state_name(), "open", "failed probe must re-open");
        assert_eq!(br.opened(), 2);
    }

    #[test]
    fn success_resets_the_failure_streak() {
        let cfg = BreakerConfig { threshold: 2, cooldown: Duration::from_millis(10) };
        let br = CircuitBreaker::new(cfg);
        br.on_failure();
        br.on_success();
        br.on_failure();
        assert_eq!(br.state_name(), "closed", "streak must reset on success");
    }
}
