//! Hand-rolled Rust lexer for the `sdm analyze` passes.
//!
//! The vendoring policy rules out `syn`/`quote` (DESIGN.md §2), and the
//! analyzer's four passes only need token streams with line numbers —
//! not a real AST — so this lexes a useful subset faithfully: idents,
//! punctuation, numbers, cooked/raw/byte strings, char literals vs
//! lifetimes, and line/block comments (captured separately, because the
//! `// lint:` / `// lock-order:` annotation grammar lives in comments).
//!
//! Known limits (documented in DESIGN.md §11): no macro expansion — a
//! macro body is lexed as the tokens it contains — and float literals /
//! suffixes are lumped into one `Num` token.

/// One lexical token. String contents are preserved (the wire-schema
/// pass reads JSON field names out of request-template literals).
#[derive(Clone, Debug, PartialEq)]
pub enum Tok {
    Ident(String),
    /// String literal (cooked, raw, or byte) — content without quotes,
    /// escapes left as written.
    Str(String),
    /// Char literal (content ignored — only lexed so `'a'` never opens a
    /// phantom string).
    Char,
    /// Lifetime like `'a` (distinguished from char literals).
    Lifetime,
    Num,
    Punct(char),
}

#[derive(Clone, Debug)]
pub struct Token {
    pub tok: Tok,
    pub line: u32,
}

/// Lexer output: the token stream plus line-indexed comment text (the
/// annotation passes walk comments by line).
#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Token>,
    /// line → comment text (after `//`, trimmed). A line holds at most
    /// one line comment; later wins (never happens in rustfmt'd code).
    pub comments: std::collections::BTreeMap<u32, String>,
}

impl Lexed {
    pub fn comment(&self, line: u32) -> Option<&str> {
        self.comments.get(&line).map(|s| s.as_str())
    }
}

pub fn lex(src: &str) -> Lexed {
    let b = src.as_bytes();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < b.len() {
        let c = b[i];
        match c {
            b'\n' => {
                line += 1;
                i += 1;
            }
            b' ' | b'\t' | b'\r' => i += 1,
            b'/' if i + 1 < b.len() && b[i + 1] == b'/' => {
                let start = i + 2;
                let mut j = start;
                while j < b.len() && b[j] != b'\n' {
                    j += 1;
                }
                let text = src[start..j].trim().to_string();
                out.comments.insert(line, text);
                i = j;
            }
            b'/' if i + 1 < b.len() && b[i + 1] == b'*' => {
                // block comment; Rust block comments nest
                let mut depth = 1;
                let mut j = i + 2;
                while j < b.len() && depth > 0 {
                    if b[j] == b'\n' {
                        line += 1;
                        j += 1;
                    } else if j + 1 < b.len() && b[j] == b'/' && b[j + 1] == b'*' {
                        depth += 1;
                        j += 2;
                    } else if j + 1 < b.len() && b[j] == b'*' && b[j + 1] == b'/' {
                        depth -= 1;
                        j += 2;
                    } else {
                        j += 1;
                    }
                }
                i = j;
            }
            b'"' => {
                let (content, j, nl) = cooked_string(src, i);
                out.tokens.push(Token { tok: Tok::Str(content), line });
                line += nl;
                i = j;
            }
            b'\'' => {
                // lifetime vs char literal: '\x', or 'c' with a closing
                // quote two ahead, is a char; otherwise a lifetime
                if i + 1 < b.len() && b[i + 1] == b'\\' {
                    let mut j = i + 2;
                    if j < b.len() {
                        j += 1; // the escaped char
                    }
                    while j < b.len() && b[j] != b'\'' {
                        j += 1; // \u{..} etc
                    }
                    out.tokens.push(Token { tok: Tok::Char, line });
                    i = (j + 1).min(b.len());
                } else if i + 2 < b.len() && b[i + 2] == b'\'' {
                    out.tokens.push(Token { tok: Tok::Char, line });
                    i = i + 3;
                } else {
                    let mut j = i + 1;
                    while j < b.len() && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
                        j += 1;
                    }
                    out.tokens.push(Token { tok: Tok::Lifetime, line });
                    i = j;
                }
            }
            _ if c.is_ascii_digit() => {
                let mut j = i + 1;
                while j < b.len()
                    && (b[j].is_ascii_alphanumeric() || b[j] == b'_' || b[j] == b'.')
                {
                    // `0..n` range: the dots belong to punctuation
                    if b[j] == b'.' && j + 1 < b.len() && b[j + 1] == b'.' {
                        break;
                    }
                    j += 1;
                }
                out.tokens.push(Token { tok: Tok::Num, line });
                i = j;
            }
            _ if c.is_ascii_alphabetic() || c == b'_' => {
                let mut j = i + 1;
                while j < b.len() && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
                    j += 1;
                }
                let ident = &src[i..j];
                // raw / byte-raw string prefixes: r"", r#""#, br"" — must
                // be handled here or embedded quotes corrupt the stream
                if (ident == "r" || ident == "br") && j < b.len() && (b[j] == b'"' || b[j] == b'#')
                {
                    if let Some((content, k, nl)) = raw_string(src, j) {
                        out.tokens.push(Token { tok: Tok::Str(content), line });
                        line += nl;
                        i = k;
                        continue;
                    }
                }
                if ident == "b" && j < b.len() && b[j] == b'"' {
                    let (content, k, nl) = cooked_string(src, j);
                    out.tokens.push(Token { tok: Tok::Str(content), line });
                    line += nl;
                    i = k;
                    continue;
                }
                out.tokens.push(Token { tok: Tok::Ident(ident.to_string()), line });
                i = j;
            }
            _ => {
                out.tokens.push(Token { tok: Tok::Punct(c as char), line });
                i += 1;
            }
        }
    }
    out
}

/// Lex a cooked string starting at the opening quote `b[start] == '"'`.
/// Returns (content, index after closing quote, newlines consumed).
fn cooked_string(src: &str, start: usize) -> (String, usize, u32) {
    let b = src.as_bytes();
    let mut j = start + 1;
    let mut nl = 0u32;
    while j < b.len() {
        match b[j] {
            b'\\' => j += 2,
            b'"' => break,
            b'\n' => {
                nl += 1;
                j += 1;
            }
            _ => j += 1,
        }
    }
    let content = src[start + 1..j.min(src.len())].to_string();
    ((content), (j + 1).min(b.len()), nl)
}

/// Lex a raw string whose hashes/quote begin at `start` (the `r`/`br`
/// prefix already consumed). Returns None if it isn't actually a raw
/// string (e.g. `r#` in an attribute-like position).
fn raw_string(src: &str, start: usize) -> Option<(String, usize, u32)> {
    let b = src.as_bytes();
    let mut hashes = 0usize;
    let mut j = start;
    while j < b.len() && b[j] == b'#' {
        hashes += 1;
        j += 1;
    }
    if j >= b.len() || b[j] != b'"' {
        return None;
    }
    j += 1;
    let content_start = j;
    let mut nl = 0u32;
    while j < b.len() {
        if b[j] == b'\n' {
            nl += 1;
            j += 1;
            continue;
        }
        if b[j] == b'"' {
            // need `hashes` following '#'
            let mut k = j + 1;
            let mut seen = 0usize;
            while k < b.len() && b[k] == b'#' && seen < hashes {
                seen += 1;
                k += 1;
            }
            if seen == hashes {
                let content = src[content_start..j].to_string();
                return Some((content, k, nl));
            }
        }
        j += 1;
    }
    Some((src[content_start..].to_string(), b.len(), nl))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(l: &Lexed) -> Vec<String> {
        l.tokens
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Ident(s) => Some(s.clone()),
                _ => None,
            })
            .collect()
    }

    fn strs(l: &Lexed) -> Vec<String> {
        l.tokens
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Str(s) => Some(s.clone()),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn basic_tokens_and_lines() {
        let l = lex("fn a() {\n  b.lock();\n}\n");
        assert_eq!(idents(&l), vec!["fn", "a", "b", "lock"]);
        let lock_tok = l
            .tokens
            .iter()
            .find(|t| t.tok == Tok::Ident("lock".into()))
            .unwrap();
        assert_eq!(lock_tok.line, 2);
    }

    #[test]
    fn comments_captured_by_line() {
        let l = lex("// lint: no-alloc\nfn f() {} // trailing\n");
        assert_eq!(l.comment(1), Some("lint: no-alloc"));
        assert_eq!(l.comment(2), Some("trailing"));
    }

    #[test]
    fn raw_strings_with_embedded_quotes() {
        let l = lex(r##"let s = r#","plan":"{p}""#;"##);
        assert_eq!(strs(&l), vec![r#","plan":"{p}""#.to_string()]);
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let l = lex("fn f<'a>(x: &'a str) { let c = 'x'; let n = '\\n'; }");
        let lifetimes = l.tokens.iter().filter(|t| t.tok == Tok::Lifetime).count();
        let chars = l.tokens.iter().filter(|t| t.tok == Tok::Char).count();
        assert_eq!(lifetimes, 2);
        assert_eq!(chars, 2);
    }

    #[test]
    fn block_comments_nest_and_count_lines() {
        let l = lex("/* outer /* inner */\nstill comment */ fn g() {}");
        assert_eq!(idents(&l), vec!["fn", "g"]);
        assert_eq!(l.tokens[0].line, 2);
    }

    #[test]
    fn cooked_string_escapes() {
        let l = lex(r#"let s = "a \"quoted\" b"; let t = "x";"#);
        assert_eq!(strs(&l).len(), 2);
        assert_eq!(strs(&l)[1], "x");
    }

    #[test]
    fn range_after_number_is_punct() {
        let l = lex("for i in 0..n {}");
        let puncts: Vec<char> = l
            .tokens
            .iter()
            .filter_map(|t| match t.tok {
                Tok::Punct(c) => Some(c),
                _ => None,
            })
            .collect();
        assert_eq!(puncts, vec!['.', '.', '{', '}']);
    }
}
