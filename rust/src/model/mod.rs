//! Model layer: the denoiser abstraction plus its two implementations —
//! the PJRT-backed AOT artifact ([`crate::model::pjrt`], the production
//! path) and the closed-form native oracle ([`gmm`], used for testing,
//! fast experiment sweeps, and as the ground-truth reference).

pub mod chaos;
pub mod datasets;
pub mod gmm;
pub mod kernel;
pub mod pjrt;

pub use datasets::{DatasetInfo, DatasetRegistry};
pub use gmm::GmmModel;
pub use kernel::{EvalScratch, KernelPrecision, KernelScratch, MaskRef};

use crate::Result;

/// Output of one fused model evaluation over a batch (row-major [B, D]).
#[derive(Clone, Debug, Default)]
pub struct EvalOut {
    /// Denoised prediction D(x̂; σ).
    pub d: Vec<f32>,
    /// Velocity v = a·x̂ + b·(x̂ − D) (true dx/dt once the caller folded
    /// the parameterization coefficients into a, b).
    pub v: Vec<f32>,
    /// Rowwise ‖v‖² computed in-kernel (feeds the curvature proxy).
    pub vnorm2: Vec<f32>,
}

impl EvalOut {
    /// Size the buffers for a `[rows, dim]` batch (grow or truncate; the
    /// into-kernels overwrite every element, so stale values never leak).
    pub fn ensure_shape(&mut self, rows: usize, dim: usize) {
        self.d.resize(rows * dim, 0.0);
        self.v.resize(rows * dim, 0.0);
        self.vnorm2.resize(rows, 0.0);
    }
}

/// The request-path model interface. Implementations must be thread-safe:
/// the coordinator calls them from batcher workers.
///
/// `denoise_v` is the required legacy entry point (allocating, per-row
/// broadcast vectors); the `*_into` methods are the allocation-free hot
/// path with default impls that adapt any legacy implementation, so
/// external wrappers keep working unchanged while the native oracle and
/// the PJRT facade override them.
pub trait Denoiser: Send + Sync {
    /// Data dimensionality D.
    fn dim(&self) -> usize;
    /// Number of mixture components K (mask width).
    fn k(&self) -> usize;
    /// Human-readable backend tag for logs/metrics.
    fn backend(&self) -> &'static str;

    /// Fused denoise + velocity over a batch.
    ///
    /// `xhat`: [rows·dim] in hat space (x/s(t)); `sigma`, `a`, `b`: [rows];
    /// `mask`: [rows·k] additive component-logit mask (0 = allowed,
    /// [`MASK_OFF`] = excluded).
    fn denoise_v(
        &self,
        xhat: &[f32],
        sigma: &[f32],
        a: &[f32],
        b: &[f32],
        mask: &[f32],
    ) -> Result<EvalOut>;

    /// [`Denoiser::denoise_v`] writing into a caller-owned [`EvalOut`].
    ///
    /// Default impl evaluates the legacy path and moves the result into
    /// `out`; allocation-free implementations overwrite `out` in place.
    #[allow(clippy::too_many_arguments)]
    fn denoise_v_into(
        &self,
        xhat: &[f32],
        sigma: &[f32],
        a: &[f32],
        b: &[f32],
        mask: &[f32],
        out: &mut EvalOut,
        scratch: &mut KernelScratch,
    ) -> Result<()> {
        let _ = scratch;
        *out = self.denoise_v(xhat, sigma, a, b, mask)?;
        Ok(())
    }

    /// Uniform-σ fast path: one scalar (σ, a, b) triple for the whole
    /// batch — the only shape [`eval_at`] ever produces — plus a
    /// [`MaskRef`] that is usually one shared row. Implementations must
    /// return outputs bit-identical to broadcasting the scalars through
    /// [`Denoiser::denoise_v`] (the kernel contract, DESIGN.md §7).
    ///
    /// Default impl stages broadcast vectors in `scratch` and calls the
    /// legacy path, so wrapper models (chaos, counting test doubles)
    /// observe exactly one `denoise_v` call per eval, as before.
    #[allow(clippy::too_many_arguments)]
    fn denoise_v_uniform_into(
        &self,
        xhat: &[f32],
        rows: usize,
        sigma: f32,
        a: f32,
        b: f32,
        mask: MaskRef<'_>,
        out: &mut EvalOut,
        scratch: &mut KernelScratch,
    ) -> Result<()> {
        let k = self.k();
        // reject wrong-shaped masks here: a bad Row would otherwise be
        // silently tiled into a wrong-shaped full mask in release builds
        mask.validate(rows, k)?;
        scratch.fill_broadcast(rows, k, sigma, a, b, mask);
        let mask_full: &[f32] = match mask {
            MaskRef::Full(m) => m,
            MaskRef::Row(_) => &scratch.mask_full,
        };
        *out = self.denoise_v(xhat, &scratch.sig_v, &scratch.a_v, &scratch.b_v, mask_full)?;
        Ok(())
    }
}

/// Additive logit value that excludes a component (matches the python
/// kernel tests' -1e30).
pub const MASK_OFF: f32 = -1.0e30;

/// Evaluate the model at integration time `t` of parameterization `p` with
/// state `x` in x-space: builds x̂ = x/s(t) and the velocity coefficients,
/// calls the fused kernel once. The returned `v` is the true dx/dt.
///
/// Convenience wrapper over [`eval_at_into`] that allocates its own
/// output and scratch — fine for one-shot callers; loops should own an
/// [`EvalScratch`] and use [`eval_at_into`] directly.
pub fn eval_at(
    model: &dyn Denoiser,
    p: crate::diffusion::Param,
    x: &[f32],
    t: f64,
    mask: &[f32],
    rows: usize,
) -> Result<EvalOut> {
    let mut out = EvalOut::default();
    let mut xhat = Vec::new();
    let mut kernel = KernelScratch::new();
    eval_at_into(model, p, x, t, MaskRef::Full(mask), rows, &mut xhat, &mut kernel, &mut out)?;
    Ok(out)
}

/// Allocation-free [`eval_at`]: σ, a, b are passed as scalars (no
/// broadcast vectors are materialized), x̂ staging reuses `xhat_buf`, and
/// the result lands in `out`. The buffers are typically fields of one
/// [`EvalScratch`], borrowed disjointly.
#[allow(clippy::too_many_arguments)]
pub fn eval_at_into(
    model: &dyn Denoiser,
    p: crate::diffusion::Param,
    x: &[f32],
    t: f64,
    mask: MaskRef<'_>,
    rows: usize,
    xhat_buf: &mut Vec<f32>,
    kernel: &mut KernelScratch,
    out: &mut EvalOut,
) -> Result<()> {
    let dim = model.dim();
    debug_assert_eq!(x.len(), rows * dim);
    let sigma = p.sigma(t);
    let s = p.s(t);
    let (a, b) = p.vel_coeffs(t);
    if s == 1.0 {
        // EDM/VE hot path: x̂ == x, skip the scale-copy entirely
        // (§Perf iteration 1 — saves one rows×dim pass per model call on
        // the two s≡1 parameterizations)
        model.denoise_v_uniform_into(x, rows, sigma as f32, a as f32, b as f32, mask, out, kernel)
    } else {
        let inv_s = (1.0 / s) as f32;
        xhat_buf.clear();
        xhat_buf.extend(x.iter().map(|v| v * inv_s));
        model.denoise_v_uniform_into(
            xhat_buf, rows, sigma as f32, a as f32, b as f32, mask, out, kernel,
        )
    }
}

/// Build an unconditional (all components allowed) mask for `rows` rows.
pub fn uncond_mask(rows: usize, k: usize) -> Vec<f32> {
    vec![0.0; rows * k]
}

/// One unconditional mask row (the shared-row form for [`MaskRef::Row`]).
pub fn uncond_mask_row(k: usize) -> Vec<f32> {
    vec![0.0; k]
}

/// One class-conditional mask row: only components whose class matches.
pub fn class_mask_row(classes: &[usize], class: usize) -> Vec<f32> {
    let k = classes.len();
    let mut row = vec![MASK_OFF; k];
    let mut any = false;
    for (i, &c) in classes.iter().enumerate() {
        if c == class {
            row[i] = 0.0;
            any = true;
        }
    }
    assert!(any, "class {class} has no mixture components");
    row
}

/// Build a class-conditional mask: only components whose class matches.
pub fn class_mask(rows: usize, classes: &[usize], class: usize) -> Vec<f32> {
    let k = classes.len();
    let row = class_mask_row(classes, class);
    let mut out = Vec::with_capacity(rows * k);
    for _ in 0..rows {
        out.extend_from_slice(&row);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_have_expected_shape() {
        let m = uncond_mask(3, 4);
        assert_eq!(m.len(), 12);
        assert!(m.iter().all(|&v| v == 0.0));

        let cm = class_mask(2, &[0, 1, 0, 2], 0);
        assert_eq!(cm.len(), 8);
        assert_eq!(cm[0], 0.0);
        assert_eq!(cm[1], MASK_OFF);
        assert_eq!(cm[2], 0.0);
        assert_eq!(cm[3], MASK_OFF);
        assert_eq!(&cm[4..], &cm[..4]);
    }

    #[test]
    fn mask_row_tiles_to_full_mask() {
        let row = class_mask_row(&[0, 1, 0, 2], 1);
        let full = class_mask(3, &[0, 1, 0, 2], 1);
        for r in 0..3 {
            assert_eq!(&full[r * 4..(r + 1) * 4], &row[..]);
        }
        assert_eq!(uncond_mask_row(5), vec![0.0; 5]);
    }

    #[test]
    #[should_panic(expected = "no mixture components")]
    fn class_mask_rejects_empty_class() {
        class_mask(1, &[0, 1], 7);
    }

    #[test]
    fn eval_out_ensure_shape_grows_and_truncates() {
        let mut o = EvalOut::default();
        o.ensure_shape(4, 3);
        assert_eq!((o.d.len(), o.v.len(), o.vnorm2.len()), (12, 12, 4));
        o.ensure_shape(2, 3);
        assert_eq!((o.d.len(), o.v.len(), o.vnorm2.len()), (6, 6, 2));
    }
}
