//! Engine hub: workload registry + model backends + schedule cache.
//!
//! The hub is the coordinator's shared state: for each dataset it holds
//! the sidecar-derived [`DatasetInfo`], a thread-safe [`Denoiser`] (PJRT
//! handle or native oracle), and a cache of built σ grids keyed by
//! [`crate::sampler::SamplerConfig::schedule_key`]-style strings. Pilot-
//! based schedules (COS, SDM) are expensive to construct — Algorithm 1
//! runs a pilot batch — so the cache is the coordinator's "state
//! management" contribution: first request pays construction, the rest
//! reuse it.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::diffusion::{Param, SigmaGrid};
use crate::model::pjrt::PjrtDenoiser;
use crate::model::{DatasetInfo, DatasetRegistry, Denoiser, GmmModel};
use crate::runtime::Runtime;
use crate::schedule::ScheduleSpec;
use crate::util::Rng;
use crate::Result;

/// Which denoiser implementation serves requests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelBackend {
    /// AOT artifact via the PJRT executor thread (production path).
    Pjrt,
    /// Closed-form oracle (tests / fast wide sweeps).
    Native,
}

impl ModelBackend {
    pub fn from_name(name: &str) -> Result<ModelBackend> {
        match name {
            "pjrt" => Ok(ModelBackend::Pjrt),
            "native" => Ok(ModelBackend::Native),
            other => anyhow::bail!("unknown backend {other:?} (pjrt|native)"),
        }
    }
}

struct DatasetEntry {
    info: DatasetInfo,
    model: Arc<dyn Denoiser>,
    /// native oracle always available (ground truth, pilot fallback)
    oracle: Arc<GmmModel>,
}

/// Shared coordinator state (cheaply cloneable via Arc by the server).
pub struct EngineHub {
    datasets: BTreeMap<String, DatasetEntry>,
    schedule_cache: Mutex<BTreeMap<String, SigmaGrid>>,
    /// kept alive so the executor thread persists as long as the hub
    _runtime: Option<Runtime>,
    pub backend: ModelBackend,
}

impl EngineHub {
    /// Load every dataset under `artifact_dir` with the chosen backend.
    pub fn load(artifact_dir: &Path, backend: ModelBackend) -> Result<EngineHub> {
        let registry = DatasetRegistry::load(artifact_dir)?;
        let runtime = match backend {
            ModelBackend::Pjrt => Some(Runtime::start(artifact_dir)?),
            ModelBackend::Native => None,
        };
        let mut datasets = BTreeMap::new();
        for (name, info) in &registry.by_name {
            let oracle = Arc::new(GmmModel::new(info.clone()));
            let model: Arc<dyn Denoiser> = match (&runtime, backend) {
                (Some(rt), ModelBackend::Pjrt) => Arc::new(PjrtDenoiser::new(
                    rt.handle.clone(),
                    name,
                    info.dim,
                    info.k,
                )),
                _ => oracle.clone(),
            };
            datasets.insert(name.clone(), DatasetEntry { info: info.clone(), model, oracle });
        }
        Ok(EngineHub {
            datasets,
            schedule_cache: Mutex::new(BTreeMap::new()),
            _runtime: runtime,
            backend,
        })
    }

    /// Build a hub over native oracles only, without artifacts on disk —
    /// used by unit tests with synthetic `DatasetInfo`s.
    pub fn from_infos(infos: Vec<DatasetInfo>) -> EngineHub {
        let mut datasets = BTreeMap::new();
        for info in infos {
            let oracle = Arc::new(GmmModel::new(info.clone()));
            datasets.insert(
                info.name.clone(),
                DatasetEntry { info, model: oracle.clone(), oracle },
            );
        }
        EngineHub {
            datasets,
            schedule_cache: Mutex::new(BTreeMap::new()),
            _runtime: None,
            backend: ModelBackend::Native,
        }
    }

    /// Build a hub with explicit serving models (the oracle is still
    /// derived from each `DatasetInfo`) — used by concurrency tests that
    /// need instrumented [`Denoiser`] implementations on the request
    /// path.
    pub fn from_models(models: Vec<(DatasetInfo, Arc<dyn Denoiser>)>) -> EngineHub {
        let mut datasets = BTreeMap::new();
        for (info, model) in models {
            let oracle = Arc::new(GmmModel::new(info.clone()));
            datasets.insert(info.name.clone(), DatasetEntry { info, model, oracle });
        }
        EngineHub {
            datasets,
            schedule_cache: Mutex::new(BTreeMap::new()),
            _runtime: None,
            backend: ModelBackend::Native,
        }
    }

    pub fn dataset_names(&self) -> Vec<String> {
        self.datasets.keys().cloned().collect()
    }

    pub fn info(&self, dataset: &str) -> Result<&DatasetInfo> {
        Ok(&self.entry(dataset)?.info)
    }

    pub fn model(&self, dataset: &str) -> Result<Arc<dyn Denoiser>> {
        Ok(self.entry(dataset)?.model.clone())
    }

    pub fn oracle(&self, dataset: &str) -> Result<Arc<GmmModel>> {
        Ok(self.entry(dataset)?.oracle.clone())
    }

    fn entry(&self, dataset: &str) -> Result<&DatasetEntry> {
        self.datasets.get(dataset).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown dataset {dataset:?}; loaded: {:?}",
                self.datasets.keys().collect::<Vec<_>>()
            )
        })
    }

    /// Resolve `steps == 0` to the dataset default.
    pub fn resolve_steps(&self, dataset: &str, steps: usize) -> Result<usize> {
        if steps > 0 {
            Ok(steps)
        } else {
            Ok(self.info(dataset)?.default_steps)
        }
    }

    /// Get or build the σ grid for a (dataset, param, schedule, steps)
    /// combination. Pilot-based schedules run their pilot on the serving
    /// model (so the PJRT path exercises the artifact end to end).
    pub fn schedule(
        &self,
        dataset: &str,
        param: Param,
        spec: &ScheduleSpec,
        steps: usize,
    ) -> Result<SigmaGrid> {
        let steps = self.resolve_steps(dataset, steps)?;
        let key = format!("{dataset}|{}|{}|{steps}", param.name(), spec.tag());
        if let Some(g) = self.schedule_cache.lock().unwrap().get(&key) {
            return Ok(g.clone());
        }
        let entry = self.entry(dataset)?;
        // deterministic pilot seed per key so cached schedules reproduce
        let seed = key.bytes().fold(0xC0FFEEu64, |h, b| {
            h.wrapping_mul(0x100000001B3).wrapping_add(b as u64)
        });
        let mut rng = Rng::new(seed);
        let grid = spec.build(steps, &entry.info, param, entry.model.as_ref(), &mut rng)?;
        self.schedule_cache
            .lock()
            .unwrap()
            .insert(key, grid.clone());
        Ok(grid)
    }

    pub fn cached_schedules(&self) -> usize {
        self.schedule_cache.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gmm::testmodel::toy;

    fn hub() -> EngineHub {
        EngineHub::from_infos(vec![toy().info])
    }

    #[test]
    fn schedule_cache_hits() {
        let h = hub();
        let spec = ScheduleSpec::Edm { rho: 7.0 };
        let g1 = h.schedule("toy", Param::Edm, &spec, 12).unwrap();
        assert_eq!(h.cached_schedules(), 1);
        let g2 = h.schedule("toy", Param::Edm, &spec, 12).unwrap();
        assert_eq!(h.cached_schedules(), 1);
        assert_eq!(g1, g2);
        // different param = different cache entry
        let _ = h.schedule("toy", Param::Ve, &spec, 12).unwrap();
        assert_eq!(h.cached_schedules(), 2);
    }

    #[test]
    fn pilot_schedules_are_cached_and_deterministic() {
        let h = hub();
        let spec = ScheduleSpec::Sdm {
            eta_min: 0.02,
            eta_max: 0.2,
            p: 1.0,
            q: 0.25,
            pilot_rows: 16,
        };
        let g1 = h.schedule("toy", Param::Edm, &spec, 10).unwrap();
        let g2 = h.schedule("toy", Param::Edm, &spec, 10).unwrap();
        assert_eq!(g1, g2);
        assert_eq!(g1.sigmas.len(), 11);
    }

    #[test]
    fn unknown_dataset_rejected() {
        let h = hub();
        assert!(h.info("nope").is_err());
        assert!(h.model("nope").is_err());
    }

    #[test]
    fn resolve_steps_default() {
        let h = hub();
        assert_eq!(h.resolve_steps("toy", 0).unwrap(), 12);
        assert_eq!(h.resolve_steps("toy", 33).unwrap(), 33);
    }
}
