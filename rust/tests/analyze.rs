//! Integration tests for `sdm analyze` (DESIGN.md §11).
//!
//! Two halves:
//!   * seeded fixtures under `rust/tests/fixtures/analyze/` — each must
//!     reproduce its golden diagnostics exactly (render format included),
//!     and each must be deny-worthy (non-empty active findings);
//!   * the self-check — analyzing the real `rust/src` against the
//!     checked-in `.lint-baseline` must yield zero active findings, with
//!     no coordinator entries hiding in the baseline.
//!
//! Tests run from the workspace root (cargo sets the test binary's cwd
//! to `CARGO_MANIFEST_DIR`), so fixture paths stay relative and the
//! golden renders are stable.

use std::path::Path;

use sdm::analyze::{analyze_tree, Report, PASS_LOCK_ORDER, PASS_NO_ALLOC, PASS_PANIC, PASS_WIRE};

fn fixture(name: &str) -> String {
    format!("rust/tests/fixtures/analyze/{name}")
}

fn analyze_fixture(root: &str) -> Report {
    analyze_tree(Path::new(root), None).expect("fixture tree scans")
}

fn renders(report: &Report, pass: &str) -> Vec<String> {
    report
        .active
        .iter()
        .filter(|d| d.pass == pass)
        .map(|d| d.render())
        .collect()
}

#[test]
fn lock_cycle_fixture_reports_both_edges() {
    let root = fixture("lock_cycle");
    let report = analyze_fixture(&root);
    assert_eq!(
        renders(&report, PASS_LOCK_ORDER),
        vec![
            format!(
                "{root}/ab.rs:14: [lock-order] lock cycle: acquires `Pair::beta` while holding \
                 `Pair::alpha` and `Pair::beta` can be held while taking `Pair::alpha` elsewhere"
            ),
            format!(
                "{root}/ab.rs:20: [lock-order] lock cycle: acquires `Pair::alpha` while holding \
                 `Pair::beta` and `Pair::alpha` can be held while taking `Pair::beta` elsewhere"
            ),
        ],
    );
    assert!(!report.active.is_empty(), "fixture must be deny-worthy");
}

#[test]
fn hidden_nested_acquisition_found_through_one_hop_of_inlining() {
    let root = fixture("lock_nested_callee");
    let report = analyze_fixture(&root);
    assert_eq!(
        renders(&report, PASS_LOCK_ORDER),
        vec![
            format!(
                "{root}/nested.rs:16: [lock-order] lock cycle: acquires `Books::ledger` while \
                 holding `Books::journal` (via call to `take_ledger`) and `Books::ledger` can \
                 be held while taking `Books::journal` elsewhere"
            ),
            format!(
                "{root}/nested.rs:27: [lock-order] lock cycle: acquires `Books::journal` while \
                 holding `Books::ledger` and `Books::journal` can be held while taking \
                 `Books::ledger` elsewhere"
            ),
        ],
    );
}

#[test]
fn coordinator_zoned_unwrap_is_flagged_and_tests_stay_exempt() {
    let root = fixture("panic_zone");
    let report = analyze_fixture(&root);
    let all: Vec<String> = report.active.iter().map(|d| d.render()).collect();
    assert_eq!(
        all,
        vec![format!(
            "{root}/coordinator/reply.rs:6: [panic-policy] panic site `unwrap` in coordinator \
             request/reply path (fn `reply_line`); return a structured error or annotate \
             `// lint: allow(panic): reason`"
        )],
        "exactly the seeded site — the #[cfg(test)] copy must not report"
    );
}

#[test]
fn no_alloc_fixture_flags_direct_and_transitive_allocation() {
    let root = fixture("no_alloc");
    let report = analyze_fixture(&root);
    let all: Vec<String> = report.active.iter().map(|d| d.render()).collect();
    assert_eq!(
        all,
        vec![
            format!("{root}/hot.rs:8: [no-alloc] no-alloc fn `hot_scale` contains `.collect()`"),
            format!(
                "{root}/hot.rs:13: [no-alloc] no-alloc fn `hot_norm` calls `helper_sum`, which \
                 allocates (`.to_vec()` at {root}/hot.rs:17)"
            ),
        ],
        "clean_axpy must stay clean, helper_sum itself is unannotated"
    );
}

#[test]
fn wire_schema_fixture_flags_both_drift_directions() {
    let root = fixture("wire_bad");
    let report = analyze_fixture(&root);
    let all: Vec<String> = report.active.iter().map(|d| d.render()).collect();
    assert_eq!(
        all,
        vec![
            format!(
                "{root}/client.rs:7: [wire-schema] wire field \"stepss\" produced here is not \
                 parsed by protocol.rs"
            ),
            format!(
                "{root}/client.rs:13: [wire-schema] wire field \"latency\" read from a reply \
                 here is never emitted by protocol.rs"
            ),
        ],
        "op/steps/ok are consistent and must not report"
    );
}

#[test]
fn every_seeded_fixture_is_deny_worthy() {
    for name in ["lock_cycle", "lock_nested_callee", "panic_zone", "no_alloc", "wire_bad"] {
        let report = analyze_fixture(&fixture(name));
        assert!(
            !report.active.is_empty(),
            "fixture `{name}` produced no findings — `sdm analyze --deny` would pass on it"
        );
    }
}

#[test]
fn passes_do_not_bleed_across_fixtures() {
    // the lock fixtures legitimately also carry panic findings (bare
    // unwraps), but must produce no wire/no-alloc noise; the wire and
    // no-alloc fixtures must stay single-pass.
    for name in ["lock_cycle", "lock_nested_callee"] {
        let report = analyze_fixture(&fixture(name));
        assert!(renders(&report, PASS_WIRE).is_empty(), "{name}");
        assert!(renders(&report, PASS_NO_ALLOC).is_empty(), "{name}");
    }
    let wire = analyze_fixture(&fixture("wire_bad"));
    assert!(renders(&wire, PASS_LOCK_ORDER).is_empty());
    assert!(renders(&wire, PASS_PANIC).is_empty());
    let hot = analyze_fixture(&fixture("no_alloc"));
    assert!(renders(&hot, PASS_LOCK_ORDER).is_empty());
    assert!(renders(&hot, PASS_PANIC).is_empty());
}

#[test]
fn real_tree_is_clean_modulo_baseline() {
    let report = analyze_tree(Path::new("rust/src"), Some(Path::new(".lint-baseline")))
        .expect("analyzing rust/src");
    assert!(
        report.active.is_empty(),
        "non-baselined findings in rust/src:\n{}",
        report
            .active
            .iter()
            .map(|d| d.render())
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert!(
        report.files_scanned > 40,
        "suspiciously few files scanned: {}",
        report.files_scanned
    );
    // the burn-down keeps paying for itself: waived findings exist, but
    // none of them live under coordinator/
    assert!(
        report.baselined.iter().all(|d| !d.file.contains("/coordinator/")),
        "baselined coordinator finding: {:?}",
        report
            .baselined
            .iter()
            .find(|d| d.file.contains("/coordinator/"))
    );
}

#[test]
fn baseline_file_has_no_coordinator_entries() {
    let text = std::fs::read_to_string(".lint-baseline").expect("baseline checked in");
    for line in text.lines().map(str::trim) {
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        assert!(
            !line.contains("coordinator/"),
            "coordinator files must stay burned down, not waived: `{line}`"
        );
    }
}
