//! Quality-vs-NFE Pareto frontier (the paper's §1 claim: SDM improves the
//! Pareto frontier of quality versus efficiency for pre-trained models).
//!
//! Sweeps the step budget for each (plan, schedule) family and reports
//! (NFE, FD) series; "who dominates where" is the reproduction target.
//! Beyond the classic single-solver arms, the table carries two segmented
//! plans (cheap solver at high σ, accurate solver through the mid band,
//! adaptive tail) and a PID-controlled adaptive arm, with per-segment NFE
//! attribution so the cost split across σ bands is visible per row.

use crate::diffusion::{CurvatureClock, Param};
use crate::experiments::{evaluate_all, ExpContext};
use crate::sampler::{SamplerConfig, SamplingPlan};
use crate::schedule::ScheduleSpec;
use crate::solvers::{LambdaKind, PidParams, SolverSpec};
use crate::Result;

/// One frontier point.
#[derive(Clone, Debug)]
pub struct ParetoPoint {
    pub family: String,
    /// full plan tag of the family's sampling plan.
    pub plan: String,
    pub steps: usize,
    pub nfe: f64,
    pub fd: f64,
    /// mean NFE attributed to each plan segment.
    pub seg_nfe: Vec<f64>,
}

/// The frontier's competing families for one (dataset, param): static
/// single-solver arms, segmented plans, and the PID-adaptive arm.
fn families(
    ctx: &ExpContext,
    dataset: &str,
    param: Param,
) -> Result<Vec<(String, SamplingPlan, ScheduleSpec)>> {
    let info = ctx.hub.info(dataset)?;
    let tau_k = match SolverSpec::sdm_default(dataset, matches!(param, Param::Vp { .. })) {
        SolverSpec::Adaptive { tau_k, .. } => tau_k,
        _ => unreachable!(),
    };
    let sdm = SolverSpec::Adaptive {
        lambda: LambdaKind::Step,
        tau_k,
        clock: CurvatureClock::Sigma,
    };
    // segment boundaries scale with the dataset's σ range (σ_max 80 puts
    // them at the canonical 2.0 / 0.5); the mid-band solver degrades from
    // dpm2m to heun off the σ domain, where dpm2m's contract fails
    let b1 = info.sigma_max * 0.025;
    let b2 = info.sigma_max * 0.00625;
    let sigma_domain = param.s(param.t_of_sigma(info.sigma_max)) == 1.0;
    let mid = if sigma_domain { "dpm2m" } else { "heun" };
    let seg_eh = SamplingPlan::parse(&format!("euler@max..{b1},{mid}@{b1}..0"))?;
    let seg_3 =
        SamplingPlan::parse(&format!("euler@max..{b1},{mid}@{b1}..{b2},sdm(tau={tau_k})@{b2}..0"))?;
    Ok(vec![
        ("euler+edm".into(), SolverSpec::Euler.into(), ScheduleSpec::Edm { rho: 7.0 }),
        ("heun+edm".into(), SolverSpec::Heun.into(), ScheduleSpec::Edm { rho: 7.0 }),
        (
            "heun+cos".into(),
            SolverSpec::Heun.into(),
            ScheduleSpec::Cos { pilot_mult: 4, pilot_rows: 128 },
        ),
        ("sdm+edm".into(), sdm.into(), ScheduleSpec::Edm { rho: 7.0 }),
        ("sdm+sdm".into(), sdm.into(), ScheduleSpec::sdm_defaults(dataset, param)),
        ("seg-eh".into(), seg_eh, ScheduleSpec::Edm { rho: 7.0 }),
        ("seg-3".into(), seg_3, ScheduleSpec::Edm { rho: 7.0 }),
        (
            "pid+edm".into(),
            SolverSpec::Pid(PidParams::default()).into(),
            ScheduleSpec::Edm { rho: 7.0 },
        ),
    ])
}

pub fn run(
    ctx: &ExpContext,
    dataset: &str,
    param: Param,
    budgets: &[usize],
) -> Result<Vec<ParetoPoint>> {
    let families = families(ctx, dataset, param)?;
    let mut cfgs = Vec::new();
    let mut meta = Vec::new();
    for (name, plan, schedule) in &families {
        for &steps in budgets {
            cfgs.push(SamplerConfig {
                dataset: dataset.to_string(),
                param,
                plan: plan.clone(),
                schedule: schedule.clone(),
                steps,
                class: None,
            });
            meta.push((name.clone(), plan.tag(), steps));
        }
    }
    let results = evaluate_all(ctx, cfgs);
    println!("Pareto frontier — {dataset} ({})", param.name());
    println!(
        "{:<12} {:>6} {:>8} {:>10}  {}",
        "family", "steps", "NFE", "FD", "NFE/segment"
    );
    let mut out = Vec::new();
    for ((family, plan, steps), r) in meta.into_iter().zip(results) {
        let r = r?;
        let seg_col = r
            .seg_nfe
            .iter()
            .map(|n| format!("{n:.1}"))
            .collect::<Vec<_>>()
            .join("/");
        println!("{:<12} {:>6} {:>8.1} {:>10.4}  {}", family, steps, r.nfe, r.fd, seg_col);
        out.push(ParetoPoint { family, plan, steps, nfe: r.nfe, fd: r.fd, seg_nfe: r.seg_nfe });
    }
    Ok(out)
}

/// Artifact-free CI smoke: one budget on the built-in toy dataset (plus
/// a SIMD-eligible synthetic when a fast tier is requested), small
/// sample count, every family (including both segmented plans and the
/// PID arm) must produce a finite frontier point. Exercised by
/// `sdm pareto --smoke [--kernel-precision <tier>]` so the plan
/// machinery — and, at a fast tier, the SIMD dispatch under it — stays
/// wired end to end.
pub fn smoke(precision: crate::model::KernelPrecision) -> Result<()> {
    use crate::coordinator::EngineHub;
    use crate::model::gmm::testmodel::{synthetic, toy};
    use crate::model::KernelPrecision;
    use std::sync::Arc;
    let hub = Arc::new(EngineHub::from_infos(vec![toy().info, synthetic(16, 64).info]));
    let ctx = ExpContext {
        samples: 512,
        rows: 256,
        seed: 11,
        threads: 4,
        hub,
        pool: None,
        precision,
    };
    if precision != KernelPrecision::Exact {
        // the toy model is below the SIMD eligibility floor; run one
        // budget on an eligible synthetic so the fast path actually fires
        let pts = run(&ctx, "synth16x64", Param::Edm, &[8])?;
        for p in &pts {
            anyhow::ensure!(p.fd.is_finite() && p.nfe > 0.0, "degenerate fast point {p:?}");
        }
    }
    let pts = run(&ctx, "toy", Param::Edm, &[8])?;
    anyhow::ensure!(pts.len() >= 8, "smoke expected every family to report");
    for p in &pts {
        anyhow::ensure!(p.fd.is_finite() && p.nfe > 0.0, "degenerate point {p:?}");
        anyhow::ensure!(!p.seg_nfe.is_empty(), "missing segment attribution {p:?}");
    }
    let seg = pts.iter().find(|p| p.family == "seg-3").expect("seg-3 family present");
    anyhow::ensure!(seg.seg_nfe.len() == 3, "seg-3 must attribute NFE to 3 segments");
    println!("pareto smoke ok: {} points", pts.len());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::EngineHub;
    use crate::model::gmm::testmodel::toy;
    use std::sync::Arc;

    #[test]
    fn frontier_shapes() {
        let hub = Arc::new(EngineHub::from_infos(vec![toy().info]));
        let ctx = ExpContext {
            samples: 2048,
            rows: 256,
            seed: 5,
            threads: 4,
            hub,
            pool: None,
            precision: Default::default(),
        };
        let pts = run(&ctx, "toy", Param::Edm, &[8, 16]).unwrap();
        assert_eq!(pts.len(), 16); // 8 families x 2 budgets
        // more steps should not hurt quality within a family (weak check:
        // euler family strictly improves from 8 to 16 steps)
        let e8 = pts.iter().find(|p| p.family == "euler+edm" && p.steps == 8).unwrap();
        let e16 = pts.iter().find(|p| p.family == "euler+edm" && p.steps == 16).unwrap();
        assert!(e16.fd < e8.fd, "euler 16-step {e16:?} vs 8-step {e8:?}");
        // heun at equal steps costs more NFE than euler
        let h8 = pts.iter().find(|p| p.family == "heun+edm" && p.steps == 8).unwrap();
        assert!(h8.nfe > e8.nfe);
        // segmented families carry per-segment attribution that sums to
        // the row's total NFE
        let seg = pts.iter().find(|p| p.family == "seg-eh" && p.steps == 8).unwrap();
        assert_eq!(seg.seg_nfe.len(), 2, "{seg:?}");
        assert_eq!(seg.seg_nfe.iter().sum::<f64>(), seg.nfe, "{seg:?}");
        assert!(seg.plan.contains("euler@max.."), "{seg:?}");
        // the PID arm reports an adaptive (non-grid) NFE
        let pid = pts.iter().find(|p| p.family == "pid+edm" && p.steps == 8).unwrap();
        assert!(pid.nfe > 0.0 && pid.fd.is_finite(), "{pid:?}");
    }

    #[test]
    fn smoke_runs_clean() {
        smoke(Default::default()).unwrap();
    }

    #[test]
    fn smoke_runs_clean_at_fast_f32() {
        smoke(crate::model::KernelPrecision::FastF32).unwrap();
    }
}
